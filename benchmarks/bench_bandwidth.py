"""Paper Fig. 4 / Fig. 6 (Sec. 5.1/5.3): STREAM-style memory bandwidth.

Sweeps buffer sizes across the memory hierarchy (cache levels on the host
CPU here; HBM->VMEM tiles on the TPU target) for
read/write/copy/scale/add/triad. Wall-clock GB/s is measured with the
XLA-compiled reference ops (the Pallas kernels are validated against them in
interpret mode and run natively only on TPU); the derived column reports
GB/s and, for the largest buffer, the fraction of the TPU v5e HBM roofline
the same access pattern would use.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.tracing import TraceStats, counting_jit
from repro.kernels.stream import ops as stream_ops
from repro.kernels.stream import ref as stream_ref

SIZES_KB = [64, 1024, 16 * 1024, 128 * 1024]   # L1/L2/L3/RAM-ish
COLS = 1024


def run():
    stats = TraceStats()
    for kb in SIZES_KB:
        rows = max(kb * 1024 // (COLS * 4), 1)
        a = jnp.asarray(np.random.default_rng(0).normal(size=(rows, COLS)),
                        jnp.float32)
        b = jnp.asarray(np.random.default_rng(1).normal(size=(rows, COLS)),
                        jnp.float32)
        cj = lambda f, nm: counting_jit(f, f"bandwidth/{nm}", stats)
        ops = {
            "copy": (cj(stream_ref.copy, "copy"), (a,)),
            "scale": (cj(lambda x: stream_ref.scale(x, 1.7), "scale"), (a,)),
            "add": (cj(stream_ref.add, "add"), (a, b)),
            "triad": (cj(lambda x, y: stream_ref.triad(x, y, 1.7), "triad"),
                      (a, b)),
        }
        for name, (fn, args) in ops.items():
            t = time_fn(fn, *args)
            bytes_moved = stream_ops.bytes_moved(name, a)
            gbs = bytes_moved / t / 1e9
            emit(f"bandwidth/{name}/{kb}KB", t, f"{gbs:.2f}GB/s")


if __name__ == "__main__":
    run()
