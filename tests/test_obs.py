"""Observability layer tests: typed events, tracer, metrics registry, and
the energy-attributed Perfetto export (round-trip + sum-to-total)."""
import json
import threading

import numpy as np
import pytest

from repro.obs import (MetricsRegistry, SpanRecord, TelemetryEvent, Tracer,
                       chrome_trace, coerce_event, events_from_meta,
                       events_to_meta, parse_chrome_trace, span_tree,
                       validate_chrome_trace, window_of, write_chrome_trace)

# -- typed telemetry events ----------------------------------------------------


def test_event_round_trip_flat_dict():
    ev = TelemetryEvent("prefill", 0.25, 32, {"s0": (1, 2)}, window=3,
                        t0=1.5, extra={"cached_tokens": 16})
    d = ev.as_dict()
    assert d["phase"] == "prefill" and d["cached_tokens"] == 16
    back = TelemetryEvent.from_dict(d)
    assert back == ev
    # mapping-style access for legacy consumers
    assert ev["wall_s"] == 0.25 and ev.get("missing") is None
    assert "cached_tokens" in ev and "window" in set(ev.keys())


def test_event_legacy_dict_coercion():
    # pre-schema log entry: no window/t0, unknown keys -> extra
    legacy = {"phase": "decode", "wall_s": 0.1, "n_tokens": 4,
              "groups": {"s1": [7]}, "batch": 4}
    ev = coerce_event(legacy)
    assert ev.window == -1 and ev.t0 == 0.0
    assert ev.groups == {"s1": (7,)} and ev.extra == {"batch": 4}
    assert window_of(ev) is None
    assert window_of(TelemetryEvent("p", 0.1, 1, {}, window=2)) == 2
    assert coerce_event(ev) is ev


def test_events_meta_round_trip():
    evs = [TelemetryEvent("prefill", 0.2, 8, {"s0": (0,)}, window=0),
           {"phase": "decode", "wall_s": 0.1, "n_tokens": 2, "groups": {}}]
    rows = events_to_meta(evs)
    assert all(isinstance(r, dict) for r in rows)
    json.dumps(rows)                               # meta footer serializable
    back = events_from_meta(rows)
    assert back[0] == evs[0]
    assert back[1].phase == "decode" and back[1].window == -1


# -- tracer --------------------------------------------------------------------


def test_tracer_nesting_and_attrs():
    tr = Tracer()
    with tr.span("outer", batch=4) as outer:
        with tr.span("inner") as inner:
            inner.set("window", 0)
        outer.update(done=True)
    recs = tr.spans()
    assert [r.name for r in recs] == ["outer", "inner"]  # start-time order
    by_name = {r.name: r for r in recs}
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["outer"].attrs == {"batch": 4, "done": True}
    assert by_name["inner"].attrs == {"window": 0}
    assert by_name["outer"].t1 >= by_name["inner"].t1 >= by_name["inner"].t0
    tree = span_tree(recs)
    assert [r.name for r in tree[None]] == ["outer"]
    assert [r.name for r in tree[by_name["outer"].span_id]] == ["inner"]


def test_tracer_begin_is_not_a_parent_and_end_idempotent():
    tr = Tracer()
    h = tr.begin("queued", track="req0")
    with tr.span("step") as sp:
        pass
    h.end(finish_reason="eos")
    h.end(finish_reason="late")                    # idempotent: no-op
    by_name = {r.name: r for r in tr.spans()}
    assert by_name["step"].parent_id is None       # begin() doesn't nest
    assert by_name["queued"].attrs == {"finish_reason": "eos"}
    assert by_name["queued"].track == "req0"


def test_tracer_error_attr_instants_and_ring_drop():
    tr = Tracer(capacity=3)
    with pytest.raises(RuntimeError):
        with tr.span("bad"):
            raise RuntimeError("boom")
    tr.instant("finish", req=7)
    for i in range(4):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 3 and tr.n_dropped == 3
    assert tr.n_started == 6
    # the ring keeps the newest history
    assert [r.name for r in tr.spans()] == ["s1", "s2", "s3"]
    tr.clear()
    assert len(tr) == 0 and tr.n_dropped == 0
    # the error attr landed before the drop; re-check on a fresh tracer
    tr2 = Tracer()
    with pytest.raises(ValueError):
        with tr2.span("bad2"):
            raise ValueError()
    assert tr2.spans()[0].attrs["error"] == "ValueError"


def test_tracer_thread_safety():
    tr = Tracer()

    def worker(k):
        for i in range(50):
            with tr.span(f"w{k}", i=i):
                pass

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = tr.spans()
    assert len(recs) == 200 and tr.n_dropped == 0
    assert len({r.span_id for r in recs}) == 200   # ids unique across threads
    # per-thread nesting stacks: top-level spans have no cross-thread parent
    assert all(r.parent_id is None for r in recs)


def test_tracer_capacity_validation():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


# -- metrics registry ----------------------------------------------------------


def test_metrics_counter_gauge_histogram():
    m = MetricsRegistry()
    m.counter("reqs").inc()
    m.counter("reqs").inc(2, reason="eos")
    assert m.counter("reqs").total() == 3.0
    with pytest.raises(ValueError):
        m.counter("reqs").inc(-1)
    m.gauge("depth").set(5)
    m.gauge("depth").add(-2)
    h = m.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count() == 3 and h.sum() == pytest.approx(5.55)
    # same name returns the same instrument; kind mismatch raises
    assert m.counter("reqs") is m.counter("reqs")
    with pytest.raises(TypeError):
        m.gauge("reqs")


def test_metrics_snapshot_byte_deterministic(tmp_path):
    def build():
        m = MetricsRegistry()
        m.counter("b_second").inc(1, zone="z2")
        m.counter("b_second").inc(2, zone="z1")
        m.counter("a_first", "help text").inc()
        m.gauge("g").set(1.25)
        m.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
        return m

    j1, j2 = build().to_json(), build().to_json()
    assert j1 == j2                                # insertion-order invariant
    assert json.loads(j1) == build().snapshot()
    p = tmp_path / "m.json"
    build().write_json(p)
    assert p.read_text() == j1


def test_metrics_prometheus_exposition():
    m = MetricsRegistry()
    m.counter("tokens", "tokens emitted").inc(5)
    m.counter("finished").inc(2, reason="eos")
    m.histogram("step_s", buckets=(0.1,)).observe(0.05)
    text = m.prometheus()
    assert "# HELP tokens tokens emitted" in text
    assert "# TYPE tokens counter" in text
    assert 'finished{reason="eos"} 2' in text
    assert 'step_s_bucket{le="0.1"} 1' in text
    assert 'step_s_bucket{le="+Inf"} 1' in text
    assert "step_s_count 1" in text


# -- export: chrome trace ------------------------------------------------------


def _spans():
    return [
        SpanRecord(0, None, "prefill", "req0", 0.0, 0.2,
                   {"window": 0, "bucket": 16}),
        SpanRecord(1, None, "decode_step", "engine", 0.2, 0.3, {"window": 1}),
        SpanRecord(2, 1, "sample", "engine", 0.25, 0.28, {}),
        SpanRecord(3, None, "finish", "req0", 0.3, 0.3, {"reason": "eos"}),
    ]


def test_chrome_trace_energy_partition_and_round_trip(tmp_path):
    energies, walls = [2.5, 1.5], [0.2, 0.1]
    doc = chrome_trace(_spans(), energies, walls, meta={"process": "t"})
    validate_chrome_trace(doc)
    od = doc["otherData"]
    assert od["energy_total_j"] == pytest.approx(4.0)
    assert od["attributed_j"] == pytest.approx(4.0)      # exact partition
    assert od["n_spans"] == 4 and od["n_windows"] == 2
    # engine track is always the top timeline row (tid 0)
    names = {ev["args"]["name"]: ev["tid"] for ev in doc["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert names["engine"] == 0

    path = tmp_path / "t.json"
    write_chrome_trace(path, _spans(), window_energies=energies,
                       window_walls=walls, meta={"process": "t"})
    recs, summary = parse_chrome_trace(path)
    assert summary["parsed_attributed_j"] == pytest.approx(
        summary["attributed_j"])
    by_id = {r.span_id: r for r in recs}
    assert by_id[0].attrs["energy_j"] == pytest.approx(2.5)
    assert by_id[1].attrs["energy_j"] == pytest.approx(1.5)
    assert by_id[2].parent_id == 1 and by_id[2].name == "sample"
    assert by_id[3].t1 == by_id[3].t0              # instant survives
    assert by_id[0].track == "req0" and by_id[0].attrs["bucket"] == 16
    assert {r.span_id for r in recs} == {0, 1, 2, 3}
    for r, p in zip(sorted(recs, key=lambda r: r.span_id), _spans()):
        assert r.t0 == pytest.approx(p.t0, abs=1e-6)
        assert r.t1 == pytest.approx(p.t1, abs=1e-6)


def test_chrome_trace_rejects_double_claimed_window():
    spans = [SpanRecord(0, None, "a", "engine", 0.0, 0.1, {"window": 0}),
             SpanRecord(1, None, "b", "engine", 0.1, 0.2, {"window": 0})]
    with pytest.raises(ValueError, match="attributed twice"):
        chrome_trace(spans, [1.0], [0.1])


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"no": "traceEvents"})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "X",
                                               "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "?", "pid": 1, "tid": 0, "ts": 0}]})


def test_write_chrome_trace_session_xor_energies(tmp_path):
    class FakeSession:
        pass

    with pytest.raises(ValueError, match="not both"):
        write_chrome_trace(tmp_path / "t.json", [], session=FakeSession(),
                           window_energies=[1.0])


# -- acceptance: live engine -> timeline, joules sum to the report -------------


@pytest.fixture(scope="module")
def engine_run():
    import jax
    from repro import configs
    from repro.models import build_model
    from repro.serve.engine import ContinuousEngine, Request

    cfg = configs.get_smoke("gemma3-27b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=32)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    stats = eng.serve(reqs)
    return eng, stats


def test_engine_trace_export_sums_to_report(engine_run, tmp_path):
    eng, _ = engine_run
    path = tmp_path / "serve.json"
    write_chrome_trace(path, eng.tracer, session=eng.tel.session,
                       meta={"process": "test"})
    recs, summary = parse_chrome_trace(path)
    report = eng.tel.session.report()
    # the ISSUE acceptance bar: per-span joules partition the session total
    assert summary["attributed_j"] == pytest.approx(report.energy_j,
                                                    abs=1e-6)
    assert summary["parsed_attributed_j"] == pytest.approx(report.energy_j,
                                                           abs=1e-6)
    # window-referencing spans partition the total; lifecycle spans also
    # carry a tag-bus energy_j attr (request energy) which is NOT part of
    # the window partition and must not be double-counted
    span_sum = sum(r.attrs.get("energy_j", 0.0) for r in recs
                   if "window" in r.attrs or "windows" in r.attrs)
    assert span_sum == pytest.approx(report.energy_j, abs=1e-6)
    # lifecycle spans present per request, engine steps on the engine track
    names = {r.name for r in recs}
    assert {"queued", "prefill", "decode", "finish",
            "decode_step"} <= names
    tracks = {r.track for r in recs}
    assert "engine" in tracks and any(t.startswith("req") for t in tracks)


def test_recorded_trace_replays_into_timeline(engine_run, tmp_path):
    from repro.obs import timeline_from_trace
    from repro.tracestore import TraceReader, record_engine

    eng, _ = engine_run
    path = tmp_path / "run.dkt"
    record_engine(eng.tel, str(path))
    doc = timeline_from_trace(TraceReader(str(path)))
    validate_chrome_trace(doc)
    od = doc["otherData"]
    # the recorded chunks carry the same joules the live session measured,
    # and every window is claimed by exactly one phase span
    assert od["attributed_j"] == pytest.approx(
        eng.tel.session.report().energy_j, abs=1e-6)
    assert od["n_spans"] == len(eng.tel.events)
    phases = {ev["name"] for ev in doc["traceEvents"] if ev["ph"] == "X"}
    assert {"prefill", "decode"} <= phases


def test_engine_metrics_registry(engine_run):
    eng, stats = engine_run
    snap = eng.metrics.snapshot()
    assert {"tokens_decoded", "requests_submitted", "requests_finished",
            "decode_step_s", "engine_energy_j"} <= set(snap)
    assert snap["decode_step_s"]["kind"] == "histogram"
    total = eng.metrics.counter("tokens_decoded").total()
    assert total == stats["tokens_decoded"] > 0
    # prometheus text renders without error and mentions the counters
    assert "tokens_decoded" in eng.metrics.prometheus()
