"""DLK005 untagged-energy-region.

The paper's measurement discipline is tag-synchronized: every sampled
window is attributed to a GPIO region or an explicit tag list, otherwise
the joules land in the untagged bucket and per-phase attribution
(prefill vs decode vs checkpoint) silently loses mass. The rule tracks
names bound to ``MonitorSession(...)`` (and ``*session`` factory
results) and flags ``.sample(...)`` calls that carry no ``tags=`` and
sit under no ``with <session>.region(...)`` block.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.core import (Finding, ModuleContext, Rule, qualname,
                                 register)

_SESSIONY = ("session", "monitor")


def _callee_is_session_factory(call: ast.Call) -> bool:
    qn = qualname(call.func).lower()
    leaf = qn.rsplit(".", 1)[-1]
    return leaf == "monitorsession" or any(s in leaf for s in _SESSIONY)


def _session_names(ctx: ModuleContext) -> Set[str]:
    """Names/attrs bound to a monitor session in this module."""
    names: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _callee_is_session_factory(node.value):
            for tgt in node.targets:
                elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
                many = len(elts) > 1
                for t in elts:
                    nm = t.id if isinstance(t, ast.Name) else \
                        t.attr if isinstance(t, ast.Attribute) else None
                    if nm is None:
                        continue
                    # tuple unpack: only the session-looking element is one
                    if many and not any(s in nm.lower() for s in _SESSIONY):
                        continue
                    names.add(nm)
        elif isinstance(node, ast.With):
            for item in node.items:
                if isinstance(item.context_expr, ast.Call) \
                        and _callee_is_session_factory(item.context_expr) \
                        and isinstance(item.optional_vars, ast.Name):
                    names.add(item.optional_vars.id)
    return names


def _receiver_name(node: ast.Attribute) -> str:
    """'session' for session.sample, 'session' for self.session.sample."""
    val = node.value
    if isinstance(val, ast.Attribute):
        return val.attr
    if isinstance(val, ast.Name):
        return val.id
    return ""


@register
class UntaggedEnergyRegion(Rule):
    """``session.sample(...)`` with no ``tags=`` outside any
    ``with session.region(...)`` block: the window's joules become
    unattributable."""

    code = "DLK005"
    name = "untagged-energy"
    #: tests exercise the sampling mechanics themselves; their windows are
    #: synthetic and attribution is meaningless there
    skip_tests = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        sessions = _session_names(ctx)
        if not sessions:
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "sample"):
                continue
            recv = _receiver_name(node.func)
            if recv not in sessions:
                continue
            if any(kw.arg == "tags" for kw in node.keywords):
                continue
            # exempt when under `with <session>.region(...)` — the GPIO
            # tag is already high for this window
            in_region = False
            for anc in ctx.ancestors(node):
                if not isinstance(anc, ast.With):
                    continue
                for item in anc.items:
                    cexpr = item.context_expr
                    if isinstance(cexpr, ast.Call) \
                            and isinstance(cexpr.func, ast.Attribute) \
                            and cexpr.func.attr == "region" \
                            and _receiver_name(cexpr.func) in sessions:
                        in_region = True
            if in_region:
                continue
            yield ctx.finding(
                self, node,
                f"'{recv}.sample(...)' has no tags= and no enclosing "
                f"'with {recv}.region(...)': the window's energy is "
                "unattributable (lands in the untagged bucket)")
