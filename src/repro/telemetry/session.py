"""``MonitorSession`` — the single host-side energy-monitoring API.

The facade over the paper's measurement platform (probe -> main board ->
GPIO tag bus, Sec. 4): a session owns one board, attaches one probe per
:mod:`power source <repro.telemetry.source>`, keeps the board clock on the
global report grid, and accumulates columnar
:class:`~repro.telemetry.samples.SampleBlock` streams.

    src = MutableSource(idle_w)
    session = MonitorSession(src, node="train-node")
    with session.region("train_step"):          # GPIO region tagging
        ...run the step...
        src.set(measured_w)
        session.sample(wall_s)                  # 1000 SPS columnar read
    report = session.report(tokens=n)           # EnergyReport: J, J/token,
                                                # per-tag J, avg W, samples

Sampling windows are aligned to the 1-kHz report grid: a sub-millisecond
step carries its fractional sample into the next window instead of silently
dropping energy, so the residual against wall time is bounded by one sample
period at all times. ``session.window()`` scopes a report to one call
(replacing the old engines' hand-rolled cursor arithmetic).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Union

from repro.core.mainboard import MainBoard
from repro.core.probe import Probe, ProbeConfig, REPORT_SPS
from repro.telemetry.samples import SampleBlock, read_board_blocks
from repro.telemetry.source import PowerSource


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Typed summary of a monitored interval.

    ``counters`` carries session-level event counts (``session.count``) —
    e.g. the serving engines' per-step jit compile counts — so compile
    activity rides the same report the energy numbers do."""

    energy_j: float
    by_tag: Dict[str, float]
    avg_power_w: float
    n_samples: int
    duration_s: float
    j_per_token: Optional[float] = None
    counters: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:
        tags = {k: round(v, 3) for k, v in sorted(self.by_tag.items())}
        jt = (f" {self.j_per_token:.4f} J/token"
              if self.j_per_token is not None else "")
        cnt = (f" counters={dict(sorted(self.counters.items()))}"
               if self.counters else "")
        return (f"{self.energy_j:.3f} J over {self.duration_s:.3f} s "
                f"({self.avg_power_w:.1f} W avg, {self.n_samples} samples)"
                f"{jt} by_tag={tags}{cnt}")


class Window:
    """A contiguous span of a session's sample stream (one engine call,
    one benchmark iteration, ...). Obtained from ``session.window()``."""

    def __init__(self, session: "MonitorSession"):
        self._session = session
        self._start = session._abs_len          # absolute block index
        self._t0 = session.cursor
        self._end: Optional[int] = None
        self._t1: Optional[float] = None

    def close(self):
        if self._end is None:
            self._end = self._session._abs_len
            self._t1 = self._session.cursor

    def blocks(self) -> List[SampleBlock]:
        end = self._end if self._end is not None else self._session._abs_len
        lo = self._start - self._session._n_dropped
        if lo < 0:
            raise RuntimeError(
                "window blocks were drained/reset out of the session; "
                "close windows before drain() or report from the drained "
                "blocks directly")
        return self._session._blocks[lo:end - self._session._n_dropped]

    def report(self, tokens: Optional[int] = None) -> EnergyReport:
        t1 = self._t1 if self._t1 is not None else self._session.cursor
        return self._session._report_over(self.blocks(), t1 - self._t0, tokens)


class MonitorSession:
    """One node's monitoring session: board + probes + tag bus + streams."""

    def __init__(self, source: Union[PowerSource, Sequence[PowerSource]],
                 node: str = "node", clock_t0: float = 0.0,
                 probe_cfg: Optional[ProbeConfig] = None,
                 grid_sps: float = REPORT_SPS,
                 oversubscribe: bool = False):
        sources = (list(source) if isinstance(source, (list, tuple))
                   else [source])
        if not sources:
            raise ValueError("MonitorSession needs at least one power source")
        self.sources = sources
        self.source = sources[0]
        self._board = MainBoard(node, clock_t0)
        base = probe_cfg or ProbeConfig()
        for i, src in enumerate(sources):
            self._board.attach(Probe(src, dataclasses.replace(
                base, probe_id=base.probe_id + i)),
                oversubscribe=oversubscribe)
        self._grid = float(grid_sps)
        self._cursor = float(clock_t0)
        self._origin = float(clock_t0)
        self._blocks: List[SampleBlock] = []
        self._n_dropped = 0          # blocks removed by drain()/reset()
        self._total_j = 0.0
        self._counters: Dict[str, float] = {}

    # -- clock / board -------------------------------------------------------

    @property
    def cursor(self) -> float:
        """Wall-time position of the session (sampling resumes here)."""
        return self._cursor

    @property
    def grid_sps(self) -> float:
        """The report grid sampling windows are aligned to."""
        return self._grid

    @property
    def board(self) -> MainBoard:
        """The underlying main board (tests / advanced wiring only)."""
        return self._board

    @property
    def tags(self):
        return self._board.tags

    # -- tagging -------------------------------------------------------------

    def region(self, name: str):
        """``with session.region("prefill"): ...`` — GPIO region tagging."""
        return self._board.tags.tag(name)

    # -- counters ------------------------------------------------------------

    def count(self, name: str, n: float = 1):
        """Bump a session-level event counter (jit compiles, cache misses,
        sheds, ...). Counters land on :class:`EnergyReport` so activity that
        burns watts without moving tokens — XLA compilation above all — is
        visible next to the energy it cost, and cleared by :meth:`reset`."""
        self._counters[name] = self._counters.get(name, 0) + n

    @property
    def counters(self) -> Dict[str, float]:
        return dict(self._counters)

    # -- sampling ------------------------------------------------------------

    def sample(self, wall_s: float, tags: Iterable[str] = ()) -> SampleBlock:
        """Sample ``wall_s`` seconds of source power through the board.

        The read is kept on the global report grid: the window's sample
        count is ``round(end*sps) - round(start*sps)``, so fractional
        periods roll into the next window (residual <= one sample period).
        Extra ``tags`` are raised for just this window; longer-lived regions
        use :meth:`region`. Returns the window's (possibly empty) block,
        concatenated over probes."""
        streams = self.sample_streams(wall_s, tags)
        return self._blocks[-1] if streams is not None else SampleBlock.empty()

    def sample_streams(self, wall_s: float,
                       tags: Iterable[str] = ()) -> Optional[Dict[int, SampleBlock]]:
        """Like :meth:`sample` but also returns the window's per-probe
        blocks keyed by probe id (the export hook recorders persist streams
        through — one ``.dkt`` stream per probe). The concatenated window
        still lands on the session's block list, so reports are unchanged.
        Returns None for a non-positive window."""
        if wall_s <= 0:
            return None
        end = self._cursor + wall_s
        read_s = (round(end * self._grid)
                  - round(self._cursor * self._grid)) / self._grid
        tags = list(tags)
        for tg in tags:
            self._board.tags.raise_(tg)
        try:
            streams = (read_board_blocks(self._board, read_s)
                       if read_s > 0 else {})
        finally:
            for tg in reversed(tags):
                self._board.tags.lower(tg)
        self._board.advance(wall_s - read_s)   # keep board clock on wall time
        self._cursor = end
        block = SampleBlock.concat(list(streams.values()))
        self._blocks.append(block)
        self._total_j += block.energy_j()
        return streams

    # -- windows / reports ---------------------------------------------------

    @contextlib.contextmanager
    def window(self):
        """Scope a report to the samples taken inside the ``with`` block."""
        w = Window(self)
        try:
            yield w
        finally:
            w.close()

    def blocks(self) -> List[SampleBlock]:
        return list(self._blocks)

    @property
    def n_windows(self) -> int:
        """Sample windows currently held (index space of the next window —
        the engines stamp this onto their telemetry events *before*
        sampling, so event ``k`` always describes block ``k``)."""
        return len(self._blocks)

    def block(self) -> SampleBlock:
        """All samples so far as one block."""
        return SampleBlock.concat(self._blocks)

    @property
    def _abs_len(self) -> int:
        """Blocks sampled over the session lifetime (drained or not);
        windows anchor on this so a drain can't silently shift them."""
        return self._n_dropped + len(self._blocks)

    def drain(self) -> List[SampleBlock]:
        """Pop the accumulated blocks (recorder flush hook): returns every
        block sampled since the last drain and clears the in-memory list so
        long recordings don't grow without bound. The clock cursor and the
        O(1) :meth:`energy_j` running total keep going; :meth:`report`
        afterwards only covers still-undrained blocks, and a ``Window``
        opened before the drain raises rather than reporting wrong energy."""
        out, self._blocks = self._blocks, []
        self._n_dropped += len(out)
        return out

    def probe_rows(self) -> List[tuple]:
        """(probe_id, bus, power_source, effective_sps, volts_nominal) per
        probe, in the board's stream order — the key recorders use to tie
        per-probe sample streams back to their power sources."""
        return [(pid, bus, probe.power_fn, sps, probe.cfg.volts_nominal)
                for pid, bus, probe, sps in self._board.probes()]

    def _report_over(self, blocks: List[SampleBlock], duration_s: float,
                     tokens: Optional[int] = None) -> EnergyReport:
        total, n = 0.0, 0
        by_tag: Dict[str, float] = {}
        for b in blocks:
            total += b.energy_j()
            n += b.n
            for k, v in b.energy_by_tag().items():
                by_tag[k] = by_tag.get(k, 0.0) + v
        return EnergyReport(
            energy_j=total, by_tag=by_tag,
            avg_power_w=total / duration_s if duration_s > 0 else 0.0,
            n_samples=n, duration_s=duration_s,
            j_per_token=(total / max(tokens, 1)
                         if tokens is not None else None))

    def energy_j(self) -> float:
        """Running session energy total (O(1); maintained as windows are
        sampled — per-step logging should use this, not ``report()``,
        which re-reduces per-tag energy over every block)."""
        return self._total_j

    def report(self, tokens: Optional[int] = None) -> EnergyReport:
        """Session-lifetime energy report (since construction or the last
        :meth:`reset`)."""
        rep = self._report_over(self._blocks, self._cursor - self._origin,
                                tokens)
        if self._counters:
            rep = dataclasses.replace(rep, counters=dict(self._counters))
        return rep

    def reset(self):
        """Drop accumulated samples and counters (benchmark warmup); the
        board clock and tag bus keep running."""
        self._n_dropped += len(self._blocks)
        self._blocks = []
        self._origin = self._cursor
        self._total_j = 0.0
        self._counters = {}
