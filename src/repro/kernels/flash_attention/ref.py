"""Pure-jnp oracle: exact softmax attention with causal/window masks."""
import jax
import jax.numpy as jnp
import numpy as np


def attention(q, k, v, causal=True, window=None):
    """q: [B,H,S,D]; k,v: [B,H,T,D]."""
    d = q.shape[-1]
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    sq, tk = q.shape[2], k.shape[2]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((sq, tk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
