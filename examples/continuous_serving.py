"""Continuous-batching serving with per-request energy attribution.

Submits a burst of mixed-length requests to the ContinuousEngine under a
node power cap and prints the per-request J/token report — the paper's
GPIO-tagged energy attribution (Sec. 4.1) driving an energy-aware serving
decision (DVFS capping + admission control, Sec. 3.6/6.1).

    PYTHONPATH=src python examples/continuous_serving.py
"""
import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve.engine import ContinuousEngine, Request


def main():
    cfg = configs.get_smoke("granite-20b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))

    engine = ContinuousEngine(model, params, batch_size=4, max_seq=64,
                              power_cap_w=150.0)
    if engine.dvfs is not None:
        print(f"power cap 150 W -> DVFS {engine.dvfs.f_ghz:.2f} GHz, "
              f"max {engine.admission.max_slots(4)} concurrent slots")

    rng = np.random.default_rng(0)
    for i in range(10):
        engine.submit(Request(
            i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 20))))

    stats = engine.run()
    print(f"\n{stats['completed']} completed, {stats['shed']} shed, "
          f"{stats['slots_recycled']} slot recycles, "
          f"peak {stats['peak_active']} active")
    print(f"decode: {stats['tokens_decoded']} tokens at "
          f"{stats['decode_tok_per_s']:.1f} tok/s")
    # the unified telemetry API: one typed report for the whole session
    report = engine.tel.session.report(tokens=stats["tokens_decoded"])
    print(f"board energy: {report}")
    print("\nper-request attribution (tag-bus bitmask shares):")
    for r in engine.finished:
        print(f"  req {r.req_id}: {len(r.output):2d} tokens "
              f"[{r.finish_reason}] {r.energy_j:6.2f} J "
              f"({r.energy_j / max(len(r.output), 1):.3f} J/token)")


if __name__ == "__main__":
    main()
