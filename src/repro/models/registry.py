"""Model registry: config -> model instance + abstract input specs.

``input_specs(cfg, shape, mesh)`` returns ShapeDtypeStruct stand-ins for every
model input of a given assigned shape cell — weak-type-correct, shardable, no
device allocation — consumed by the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.mamba2 import Zamba2
from repro.models.transformer import DecoderLM
from repro.models.whisper import Whisper
from repro.models.xlstm import XLSTM


def build_model(cfg: ModelConfig, mesh=None, **kw):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, mesh, **kw)
    if cfg.family == "ssm":
        return XLSTM(cfg, mesh, **kw)
    if cfg.family == "hybrid":
        return Zamba2(cfg, mesh, **kw)
    if cfg.family == "audio":
        return Whisper(cfg, mesh, **kw)
    raise ValueError(f"unknown family {cfg.family}")


def abstract_params(model):
    """(ShapeDtypeStruct params tree, logical-axes tree) without allocation."""
    return model.init(None)  # ParamBuilder abstract mode


def token_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Training/prefill batch ShapeDtypeStructs for this arch."""
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeddings"] = jax.ShapeDtypeStruct(
            (b, cfg.stub_prefix_len, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return batch
