"""INA228-probe model (paper Sec. 4.2).

A probe sits between the supply and the node, samples V/I at 4000 SPS, and
reports 4-sample averages (1000 SPS) with milliwatt resolution. The paper
trades the INA228's max 10000 SPS down to 4000 SPS for resolution; we model
exactly the reported configuration: each emitted sample carries the averaged
voltage, current, power, and the number of raw measurements averaged.

The probe is *driven* by a power model (``power_fn(t) -> W``): in deployment
that is the physical node; here it is the simulated node power trace (DVFS
model x utilization), which lets every energy experiment in the paper run
bit-faithfully on this cluster-less container.

Two read paths share one arithmetic pipeline (clip -> noise -> floor ->
average -> mW quantize), so they agree bit-for-bit:

``Probe.read``        per-object ``Sample`` list (legacy hosts/tests);
``Probe.read_block``  columnar ``(t, watts)`` arrays — the default under
                      ``repro.telemetry`` — evaluating the power function on
                      whole timestamp arrays when it supports that.

Both accept a report rate ``sps`` below ``REPORT_SPS``: an oversubscribed
I2C bus ships fewer reports per probe (decimation), so the averaging window
of each surviving report stays the INA228's 4-raw-sample configuration while
the stream's integration dt grows to ``1/sps``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

RAW_SPS = 4000          # INA228 configured rate (paper: reduced from 10000)
AVG_N = 4               # samples averaged per report
REPORT_SPS = RAW_SPS // AVG_N   # 1000 SPS
MILLIWATT = 1e-3        # reported resolution
MAX_PD_WATTS = 240.0    # USB PD 3.1 probe limit (paper Sec. 4.2)


@dataclasses.dataclass(frozen=True)
class Sample:
    """One averaged report (paper: V, I, P + averaging count)."""

    t: float            # seconds since stream start
    volts: float
    amps: float
    watts: float
    n_avg: int
    tags: tuple = ()    # GPIO tags active when the sample was taken
    dt: float = 1.0 / REPORT_SPS    # this report's integration period


@dataclasses.dataclass
class ProbeConfig:
    probe_id: int = 0
    volts_nominal: float = 20.0      # USB-PD rail
    noise_w: float = 0.005           # measurement noise (W, std)
    max_watts: float = MAX_PD_WATTS
    seed: int = 0


def _eval_power(power_fn: Callable, t: np.ndarray) -> np.ndarray:
    """Evaluate ``power_fn`` over a timestamp array, vectorized when the
    function supports arrays. Scalar-only functions (TypeError/ValueError
    on array input, or a scalar result) fall back to a per-element loop;
    any other exception is a real bug in the power function and propagates."""
    try:
        w = np.asarray(power_fn(t), dtype=np.float64)
    except (TypeError, ValueError):
        w = None
    if w is not None and w.shape == t.shape:
        return w
    if w is not None and w.shape == ():
        return np.full(t.shape, float(w))
    return np.fromiter((float(power_fn(x)) for x in t), np.float64,
                       count=t.size).reshape(t.shape)


def _report_grid(t0: float, duration: float,
                 sps: float) -> Tuple[np.ndarray, np.ndarray]:
    """(report timestamps [n], raw timestamps [n, AVG_N]) for a read.

    Reports land at ``t0 + (i+1)/sps``; each averages the AVG_N raw
    conversions immediately preceding it at RAW_SPS spacing (at full rate
    this is exactly the contiguous 4000 SPS raw stream)."""
    n = int(round(duration * sps))
    t_rep = t0 + (np.arange(n, dtype=np.float64) + 1) / sps
    offs = (np.arange(AVG_N, dtype=np.float64) - (AVG_N - 1)) / RAW_SPS
    return t_rep, t_rep[:, None] + offs[None, :]


def _pipeline(raw_w: np.ndarray, cfg: ProbeConfig,
              rng: np.random.Generator) -> np.ndarray:
    """clip -> noise -> floor -> average -> mW quantize (shared by all
    read paths; identical arithmetic order keeps them bit-equal)."""
    w = np.clip(raw_w, 0.0, cfg.max_watts)
    w = np.maximum(w + rng.normal(0.0, cfg.noise_w, w.shape), 0.0)
    watts = w.mean(axis=-1)
    return np.round(watts / MILLIWATT) * MILLIWATT


class Probe:
    """Streams averaged samples from a power function."""

    def __init__(self, power_fn: Callable[[float], float],
                 cfg: Optional[ProbeConfig] = None):
        self.power_fn = power_fn
        self.cfg = cfg or ProbeConfig()
        self._rng = np.random.default_rng(self.cfg.seed + self.cfg.probe_id)

    def read_block(self, t0: float, duration: float,
                   sps: Optional[float] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Columnar read: ``(t [n], watts [n])`` at ``sps`` reports/s
        (default ``REPORT_SPS``) in (t0, t0+duration]."""
        cfg = self.cfg
        t_rep, t_raw = _report_grid(t0, duration, sps or REPORT_SPS)
        raw_w = _eval_power(self.power_fn, t_raw.ravel()).reshape(t_raw.shape)
        return t_rep, _pipeline(raw_w, cfg, self._rng)

    def read(self, t0: float, duration: float,
             sps: Optional[float] = None) -> List[Sample]:
        """Samples in (t0, t0+duration] as ``Sample`` objects, carrying the
        stream's actual report period (``1/sps``) for energy integration."""
        cfg = self.cfg
        t_rep, watts = self.read_block(t0, duration, sps)
        volts = cfg.volts_nominal
        dt = 1.0 / (sps or REPORT_SPS)
        return [Sample(float(t), volts,
                       round(float(w) / volts, 6) if volts else 0.0,
                       float(w), AVG_N, dt=dt)
                for t, w in zip(t_rep, watts)]


def read_vectorized(power_fn, t0: float, duration: float,
                    cfg: Optional[ProbeConfig] = None,
                    sps: Optional[float] = None) -> np.ndarray:
    """Vectorized one-shot read (fresh rng from the config seed): returns
    [n, 2] (t, watts). ``Probe.read_block`` is the stateful equivalent."""
    cfg = cfg or ProbeConfig()
    rng = np.random.default_rng(cfg.seed + cfg.probe_id)
    t_rep, t_raw = _report_grid(t0, duration, sps or REPORT_SPS)
    raw_w = _eval_power(power_fn, t_raw.ravel()).reshape(t_raw.shape)
    return np.stack([t_rep, _pipeline(raw_w, cfg, rng)], axis=1)
