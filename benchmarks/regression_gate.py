"""Cross-run bench regression gate.

Diffs the current run's ``--json`` bench rows against the previous run's
uploaded artifact and fails (exit 1) on:

- a relative slowdown beyond ``--threshold`` (default 15%) on any row's
  ``us_per_call``, or
- ANY increase in a row's ``compiles`` field — compile counts are a serving
  invariant (prefill executables are bounded by the bucket count), so a
  single new executable means some change reintroduced a retrace and is
  silently burning watts on XLA compilation instead of tokens, or
- ANY decrease in a row's ``hit_rate`` field — the prefix-cache hit rate on
  the shared-prefix workload is deterministic, so a drop means a sharing
  regression (trie matching, block refcounts, admission) is silently
  recomputing prefill work the cache used to serve for free, or
- ANY increase in a row's ``findings`` field — the ``repro.analysis``
  linter (``--gate-json``) emits one row per rule with its non-suppressed
  finding count; an increase means a new DLK violation landed without a
  pragma or a fix, or
- ANY drift in a replay-report row (``launch.replay --json``: rows carrying
  ``attributed_j``) — replay is a pure function of (trace bytes, workload,
  policy) and the CI trace is recorded from seeded sources, so energies are
  bit-stable across runs and counts (completed/shed/tokens) are exact; any
  change means an admission-policy or attribution regression, or
- a ``budget`` row exceeding its ceiling: a row shaped
  ``{"value": v, "budget": b}`` fails whenever ``v > b``, *including on the
  first run with no previous artifact* — absolute acceptance bars (e.g. the
  serving bench's span-emission overhead, <5% decode tokens/s) gate
  themselves rather than only gating drift.

``--history FILE`` appends one record per gated artifact (rows + failure
strings, plus ``--run-id`` when given) to a JSON list that CI carries
forward as an artifact — the cross-run trajectory is inspectable instead
of only the last pairwise diff.

Rows carrying a ``compiles`` field are *only* gated on the compile count:
their wall time is cold-compile-dominated by design, which swings well past
any reasonable threshold across differently-provisioned CI runners with
zero code change. The deterministic count is the signal; the time is noise.

Rows present only in one file are reported but never fail the gate (new
benches must be able to land; deleted benches must not wedge CI forever).

    python -m benchmarks.regression_gate PREV.json CURRENT.json
    python -m benchmarks.regression_gate --prev-dir prev/ --cur-dir . \
        [--threshold 0.15] [--pattern "BENCH_*.json"]

Directory mode pairs files by basename, so one invocation gates every
artifact the CI perf-trajectory job uploads (serving, energy platform,
scheduler, roofline).
"""
import argparse
import glob
import json
import os
import sys

# rows cheaper than this are timer noise on shared CI runners; the compile
# gate still applies to them, only the slowdown check is skipped
MIN_GATED_US = 50.0


def load_rows(path):
    with open(path) as f:
        return json.load(f)


def diff_rows(name, prev, cur, threshold):
    """Compare one artifact's row dicts; returns a list of failure strings."""
    failures = []
    common = sorted(set(prev) & set(cur))
    for row in common:
        p, c = prev[row], cur[row]
        compile_row = "compiles" in p or "compiles" in c
        p_us, c_us = p.get("us_per_call", 0.0), c.get("us_per_call", 0.0)
        if (not compile_row and p_us >= MIN_GATED_US
                and c_us > p_us * (1.0 + threshold)):
            failures.append(
                f"{name}:{row}: {p_us:.1f}us -> {c_us:.1f}us "
                f"(+{(c_us / p_us - 1.0) * 100:.1f}% > "
                f"{threshold * 100:.0f}% threshold)")
        p_comp, c_comp = p.get("compiles"), c.get("compiles")
        if p_comp is not None and c_comp is not None and c_comp > p_comp:
            failures.append(
                f"{name}:{row}: compile count regressed "
                f"{p_comp} -> {c_comp} (any increase fails: a retrace "
                f"was reintroduced)")
        p_hit, c_hit = p.get("hit_rate"), c.get("hit_rate")
        if p_hit is not None and c_hit is not None and c_hit < p_hit - 1e-6:
            failures.append(
                f"{name}:{row}: prefix-cache hit rate regressed "
                f"{p_hit:.3f} -> {c_hit:.3f} (any decrease fails: a "
                f"sharing regression is recomputing cached prefill work)")
        p_find, c_find = p.get("findings"), c.get("findings")
        if p_find is not None and c_find is not None and c_find > p_find:
            failures.append(
                f"{name}:{row}: static-analysis findings regressed "
                f"{p_find} -> {c_find} (any increase fails: a new "
                f"dalek-lint violation landed without a fix or pragma)")
        # replay-report rows (launch.replay --json) are bit-deterministic:
        # energies must match to float tolerance, counts exactly
        if "attributed_j" in p and "attributed_j" in c:
            for fld in ("energy_j", "attributed_j", "per_request_j"):
                pv, cv = p.get(fld), c.get(fld)
                if pv is not None and cv is not None and abs(cv - pv) > 1e-6:
                    failures.append(
                        f"{name}:{row}: replay {fld} drifted "
                        f"{pv:.6f} -> {cv:.6f} J (replay is deterministic; "
                        f"any drift is an attribution/policy regression)")
            for fld in ("completed", "shed", "tokens"):
                pv, cv = p.get(fld), c.get(fld)
                if pv is not None and cv is not None and cv != pv:
                    failures.append(
                        f"{name}:{row}: replay {fld} changed {pv} -> {cv} "
                        f"(admission decisions on a recorded trace must be "
                        f"reproducible)")
    for row in sorted(set(cur) - set(prev)):
        print(f"  [new row, not gated] {name}:{row}")
    for row in sorted(set(prev) - set(cur)):
        print(f"  [row disappeared, not gated] {name}:{row}")
    return failures


def check_budgets(name, rows):
    """Absolute ceilings: rows shaped {"value": v, "budget": b} fail on
    v > b. Applied to every *current* artifact — paired or not — so a new
    budget row gates itself from its first run."""
    failures = []
    for row in sorted(rows):
        r = rows[row]
        if not isinstance(r, dict) or "budget" not in r or "value" not in r:
            continue
        v, b = r["value"], r["budget"]
        if v > b:
            failures.append(
                f"{name}:{row}: value {v:.4f} exceeds budget {b:.4f} "
                f"(absolute ceiling, gated even without a previous artifact)")
        else:
            print(f"  [budget ok] {name}:{row}: {v:.4f} <= {b:.4f}")
    return failures


def append_history(path, run_id, artifacts, failures):
    """Append one record per gate invocation to a JSON-list history file."""
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
            if not isinstance(history, list):
                history = []
        except (json.JSONDecodeError, OSError):
            history = []
    history.append({
        "run_id": run_id,
        "passed": not failures,
        "failures": failures,
        "artifacts": artifacts,
    })
    with open(path, "w") as f:
        json.dump(history, f, indent=1, sort_keys=True)
    print(f"gate history -> {path} ({len(history)} record(s))")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="PREV.json CURRENT.json (file mode)")
    ap.add_argument("--prev-dir", default=None)
    ap.add_argument("--cur-dir", default=None)
    ap.add_argument("--pattern", default="BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max relative us_per_call slowdown (0.15 = 15%%)")
    ap.add_argument("--history", default=None,
                    help="JSON file to append this run's gate record to "
                         "(rows + failures); CI carries it forward as an "
                         "artifact so the trajectory is inspectable")
    ap.add_argument("--run-id", default="",
                    help="opaque id stamped into --history records "
                         "(e.g. $GITHUB_RUN_ID)")
    args = ap.parse_args(argv)

    pairs = []       # artifacts with a previous counterpart
    unpaired = []    # current-only artifacts (still budget-checked)
    if args.prev_dir and args.cur_dir:
        cur_files = sorted(glob.glob(os.path.join(args.cur_dir, args.pattern)))
        if not cur_files:
            print(f"no artifacts matching {args.pattern} in {args.cur_dir}")
            return 1
        for cur in cur_files:
            base = os.path.basename(cur)
            prev = os.path.join(args.prev_dir, base)
            if os.path.exists(prev):
                pairs.append((base, prev, cur))
            else:
                print(f"  [no previous artifact, drift not gated] {base}")
                unpaired.append((base, cur))
    elif len(args.files) == 2:
        pairs.append((os.path.basename(args.files[1]), *args.files))
    else:
        ap.error("pass PREV.json CURRENT.json or --prev-dir/--cur-dir")

    failures = []
    artifacts = {}
    for name, prev, cur in pairs:
        print(f"gate: {prev} vs {cur}")
        cur_rows = load_rows(cur)
        artifacts[name] = cur_rows
        failures += diff_rows(name, load_rows(prev), cur_rows, args.threshold)
        failures += check_budgets(name, cur_rows)
    for name, cur in unpaired:
        cur_rows = load_rows(cur)
        artifacts[name] = cur_rows
        failures += check_budgets(name, cur_rows)

    if args.history:
        append_history(args.history, args.run_id, artifacts, failures)

    if failures:
        print(f"\nREGRESSION GATE FAILED ({len(failures)}):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nregression gate passed ({len(pairs)} paired + "
          f"{len(unpaired)} budget-only artifact(s), "
          f"threshold {args.threshold * 100:.0f}%, compile counts pinned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
