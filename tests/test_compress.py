"""Gradient compression tests: int8 pod-axis all-reduce correctness, error
feedback convergence, wire-size accounting."""
import functools
import os

import numpy as np
import pytest

# need >1 device for a pod axis: re-exec guard via XLA flag is handled in
# conftest-free style — these tests use the CPU host-device trick only if
# the process was started with it; otherwise they run the single-pod path.
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import compress


def test_blockwise_quantization_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(10_000,)) * 3.0, jnp.float32)
    q, scale = compress._quantize_blockwise(x)
    approx = compress._dequantize(q, scale, x.shape[0])
    blocks = np.asarray(x[: (10_000 // 256) * 256]).reshape(-1, 256)
    bound = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
    err = np.abs(np.asarray(approx - x))[: blocks.size].reshape(-1, 256)
    assert (err <= bound / 2 + 1e-7).all()


def test_error_feedback_unbiased_over_time():
    """Sum of compressed updates converges to sum of true gradients."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(512, np.float32)
    applied_sum = np.zeros(512, np.float32)
    err = jnp.zeros(512, jnp.float32)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
        true_sum += np.asarray(g)
        v = g + err
        q, scale = compress._quantize_blockwise(v)
        approx = compress._dequantize(q, scale, 512)
        err = v - approx
        applied_sum += np.asarray(approx)
    # residual bounded by one quantization step, NOT growing with steps
    resid = np.abs(true_sum - applied_sum)
    assert resid.max() < 0.2


def test_compression_ratio():
    r = compress.compression_ratio(1_000_000)
    assert 3.5 < r < 4.0


def test_compressed_psum_matches_fp32_within_tolerance():
    if jax.device_count() < 2:
        pytest.skip("needs multi-device (run under dry-run env)")
    mesh = jax.make_mesh((2,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(2)
    per_pod = jnp.asarray(rng.normal(size=(2, 1024)), jnp.float32)

    @functools.partial(jax.shard_map, mesh=mesh, in_specs=P("pod"),
                       out_specs=P("pod"))
    def run(v):
        out = compress.compressed_psum_pod(v[0], axis_name="pod")
        return out[None]

    got = np.asarray(run(per_pod))[0]
    want = np.asarray(per_pod).mean(axis=0)
    scale = np.abs(np.asarray(per_pod)).max() / 127
    np.testing.assert_allclose(got, want, atol=2 * scale)
