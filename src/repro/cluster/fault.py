"""Fault tolerance: failure injection/detection, checkpoint-restart, elastic
re-meshing.

At thousand-node scale the framework must assume node loss is routine. The
contract implemented here:

  - heartbeat-based detection (miss k beats -> dead);
  - training state is periodically checkpointed (atomic, see checkpoint.ckpt);
  - on failure, the run shrinks to the surviving node set: a new (smaller)
    mesh is built, the last committed checkpoint is restored with the new
    shardings, and training resumes (elastic scaling DOWN);
  - recovered/new nodes rejoin at the next checkpoint boundary (scaling UP);
  - DALEK semantics: failed nodes are power-cycled via the elastic
    controller (WoL), with boot latency before rejoin.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class HeartbeatMonitor:
    nodes: Dict[str, float] = dataclasses.field(default_factory=dict)
    interval_s: float = 10.0
    miss_limit: int = 3

    def beat(self, node: str, t: float):
        self.nodes[node] = t

    def dead(self, t: float) -> List[str]:
        limit = self.interval_s * self.miss_limit
        return [n for n, last in self.nodes.items() if t - last > limit]

    def alive(self, t: float) -> List[str]:
        limit = self.interval_s * self.miss_limit
        return [n for n, last in self.nodes.items() if t - last <= limit]


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/simulation: MTBF model."""

    mtbf_s: float
    seed: int = 0

    def schedule(self, nodes: Sequence[str], horizon_s: float) -> List[tuple]:
        rng = np.random.default_rng(self.seed)
        events = []
        for n in nodes:
            t = float(rng.exponential(self.mtbf_s))
            while t < horizon_s:
                events.append((t, n))
                t += float(rng.exponential(self.mtbf_s))
        return sorted(events)


@dataclasses.dataclass
class ElasticRunState:
    """What the orchestrator tracks for one elastic training run."""

    step: int = 0
    n_workers: int = 0
    restarts: int = 0
    lost_steps: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)


class ElasticTrainOrchestrator:
    """Drives an elastic training run against failures.

    Pluggable callbacks keep it testable and backend-agnostic:
      build(n_workers)            -> opaque 'session' (mesh+jit+state)
      restore(session, ckpt_step) -> start_step
      train_chunk(session, start, n) -> last_completed_step
      save(session, step)         -> None  (atomic commit)
    """

    def __init__(self, *, build, restore, train_chunk, save,
                 ckpt_every: int = 50, min_workers: int = 1):
        self.build = build
        self.restore = restore
        self.train_chunk = train_chunk
        self.save = save
        self.ckpt_every = ckpt_every
        self.min_workers = min_workers
        self.state = ElasticRunState()

    def run(self, total_steps: int, initial_workers: int,
            failure_events: Optional[List[tuple]] = None,
            step_time_s: float = 1.0):
        """Simulated-time elastic run; failure_events: [(t_s, node_idx)]."""
        st = self.state
        st.n_workers = initial_workers
        failure_events = sorted(failure_events or [])
        fe_i = 0
        t = 0.0
        session = self.build(st.n_workers)
        last_ckpt = 0
        step = self.restore(session, None)
        st.step = step
        while st.step < total_steps:
            chunk = min(self.ckpt_every - (st.step % self.ckpt_every) or
                        self.ckpt_every, total_steps - st.step)
            chunk_end_t = t + chunk * step_time_s
            # does a failure land inside this chunk?
            if (fe_i < len(failure_events)
                    and failure_events[fe_i][0] < chunk_end_t
                    and st.n_workers - 1 >= self.min_workers):
                ft, _node = failure_events[fe_i]
                fe_i += 1
                done = int((ft - t) / step_time_s)
                st.lost_steps += st.step + done - last_ckpt
                st.events.append({"t": ft, "kind": "failure",
                                  "workers": st.n_workers - 1})
                # shrink, rebuild, restore from last commit
                st.n_workers -= 1
                st.restarts += 1
                session = self.build(st.n_workers)
                st.step = self.restore(session, last_ckpt)
                t = ft
                continue
            st.step = self.train_chunk(session, st.step, chunk)
            t = chunk_end_t
            self.save(session, st.step)
            last_ckpt = st.step
            st.events.append({"t": t, "kind": "ckpt", "step": st.step})
        return st
