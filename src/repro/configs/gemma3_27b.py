"""gemma3-27b — dense, 5:1 local:global attention, qk-norm, 128k ctx
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
    d_ff=21504, vocab_size=262144, head_dim=128,
    qk_norm=True, sliding_window=1024, local_global_period=6,
    rope_theta=1_000_000.0,
    # NOT subquadratic: global layers (every 6th) are full attention.
    source="hf:google/gemma-3-1b-pt",
)

SMOKE_CONFIG = CONFIG.replace(
    name="gemma3-27b-smoke", num_layers=6, d_model=128, num_heads=8,
    num_kv_heads=4, d_ff=256, vocab_size=512, head_dim=16,
    sliding_window=32, local_global_period=3,
)
