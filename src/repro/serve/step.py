"""Serving steps: prefill (builds KV caches / recurrent state) and decode
(one new token against a cache of ``seq_len``). Cache sharding comes from the
model's ``cache_axes()`` logical axes; for batch=1 long-context decode the
``kv_seq`` rule is overridden to sequence-shard the cache (context/SP).

``make_decode_step`` fuses sampling into the jitted step so the host loop
syncs once per step for the whole batch (one [B,1] token fetch) instead of
once per slot; ``pos`` may be a [B] vector for continuous batching.
``make_slot_prefill`` prefills a single request into one batch row of the
shared cache while the other rows keep their in-flight state."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import gather_cache_slot, scatter_cache_slot
from repro.parallel.sharding import spec_for


def make_prefill_step(model):
    def prefill_step(params, batch, caches):
        logits, caches = model.prefill(params, batch, caches)
        return logits, caches
    return prefill_step


def make_decode_step(model, greedy=True):
    """Fused decode + in-jit sampling. ``pos``: scalar or [B] int32."""
    def decode_step(params, tokens, pos, caches, key=None):
        logits, caches = model.decode_step(params, tokens, pos, caches)
        if greedy or key is None:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            next_tok = jax.random.categorical(key, logits).astype(jnp.int32)
        return next_tok, logits, caches
    return decode_step


def make_slot_prefill(model):
    """Prefill one request ([1, S] tokens) into batch row ``slot`` of a
    shared cache pytree; every other row is untouched. Distinct prompt
    lengths retrace (jit caches one executable per S)."""
    def slot_prefill(params, tokens, slot, caches):
        sub = gather_cache_slot(caches, slot)
        logits, sub = model.prefill(params, {"tokens": tokens}, sub)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, scatter_cache_slot(caches, sub, slot)
    return slot_prefill


def serve_rules(shape):
    """Sharding-rule overrides per shape cell.

    batch=1 (long_500k): nothing to shard on batch -> sequence-shard KV
    caches over ("pod","data") and keep TP on heads.
    """
    if shape.global_batch == 1:
        return {"batch": None, "kv_seq": ("pod", "data")}
    return {}


def cache_specs(mesh, model, cache_sds, rules=None):
    axes = model.cache_axes()
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda a, c: spec_for(mesh, a, c.shape, rules),
        axes, cache_sds, is_leaf=is_axes)


def abstract_cache(model, batch_size, max_seq, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model.init_cache(batch_size, max_seq, dtype))
