"""Serving engines with energy-attributed telemetry.

Two engines share one telemetry pipeline (a ``repro.telemetry``
``MonitorSession`` over the paper Sec. 4.1 probe/board/tag-bus platform),
with power traces *derived* from the roofline/DVFS energy model
(``core.energy.ServePowerModel``) — no hardcoded watt constants:

``ServeEngine``      static-batch baseline: one padded prefill, lock-step
                     decode until every request in the batch finishes.
``ContinuousEngine`` true continuous batching: admission-controlled request
                     queue, per-slot state behind a ``serve.state``
                     ``CacheAdapter`` (paged KV, window rings, or recurrent
                     carried state — selected by the family's declared
                     ``ServingCaps``), fused jitted decode with per-slot
                     positions (one host sync per step), slot recycling so
                     new requests join mid-decode, per-request J/token
                     attribution via GPIO slot tags, and an energy-aware
                     admission policy (DVFS power capping + TTL shedding
                     from measured throughput).

The engine never inspects model methods or cache layouts: every family in
``repro.configs`` — transformers (paged or ring), SSM/hybrid, whisper —
serves through the same loop, and the adapter owns the layout-specific
steps. Prefill compile counts stay bounded (bucket edges for the
transformer families, power-of-two chunk sizes for the recurrent ones);
every jitted step runs through ``serve.step.counting_jit`` and the counts
are exposed in the run stats (``prefill_compiles``/``decode_compiles``),
as telemetry counters on the ``MonitorSession`` report, and
regression-gated in CI — unbounded compilation silently dominates the
J/token numbers the platform exists to measure.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import ServePowerModel
from repro.core.hw import DeviceSpec, TPU_V5E
from repro.core.scheduler import ThroughputStats
from repro.core.tags import N_GPIO
from repro.obs import NULL_SPAN, MetricsRegistry, TelemetryEvent, Tracer
from repro.serve.queue import AdmissionController, Request, RequestQueue
from repro.serve.slots import SlotManager
from repro.serve.state import make_adapter, resolve_buckets
from repro.serve.step import (TraceStats, bucket_for, counting_jit,
                              make_decode_step, make_prefill_step)
from repro.telemetry import ModelSource, MonitorSession

__all__ = ["Request", "ServeEngine", "ContinuousEngine", "EngineTelemetry",
           "resolve_buckets"]


def _count_params(params) -> float:
    return float(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params)))


def _cache_bytes(model, batch_size, max_seq) -> float:
    """KV-cache footprint (bytes) without allocating it."""
    sds = jax.eval_shape(lambda: model.init_cache(batch_size, max_seq))
    return float(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                     for l in jax.tree.leaves(sds)))


class EngineTelemetry:
    """Engine-side policy over a ``repro.telemetry`` ``MonitorSession``.

    Phase tags ("prefill"/"decode") use two GPIO channels; the remaining
    channels carry per-slot tags so board energy can be attributed to the
    request owning each slot. With more slots than spare channels, slots
    share tags round-robin and a shared tag's energy splits equally among
    its active slots (board power is one stream; concurrent attribution
    needs a split policy — we use equal shares).
    """

    N_PHASE_TAGS = 2

    def __init__(self, power_model: ServePowerModel, batch_size: int,
                 node: str = "serve-node",
                 metrics: Optional[MetricsRegistry] = None):
        self.pm = power_model
        self.source = ModelSource(power_model)
        self.session = MonitorSession(self.source, node=node)
        self.n_slot_tags = max(1, min(batch_size, N_GPIO - self.N_PHASE_TAGS))
        self.metrics = metrics
        # per-window event log: what replay needs to re-drive this session
        # deterministically against a recorded trace (repro.tracestore),
        # and what the timeline exporter (repro.obs.export) merges with the
        # span stream — typed schema shared by both consumers
        self.events: List[TelemetryEvent] = []

    def slot_tag(self, slot_index: int) -> str:
        return f"s{slot_index % self.n_slot_tags}"

    def record(self, phase: str, wall_s: float, n_tokens: int,
               slot_to_req: Dict[int, Request],
               extra: Optional[Dict] = None) -> Optional[TelemetryEvent]:
        """Sample ``wall_s`` of board power under ``phase`` + slot tags and
        attribute each sample's energy to the requests owning the slots
        (vectorized bitmask share computation on the columnar block).

        ``session.sample`` keeps windows on the global 1-kHz grid, so
        sub-millisecond steps carry their fraction into the next window
        instead of silently dropping energy. ``n_tokens`` is the *computed*
        token count — a prefix-cache-served span burns no board time, so the
        engine passes only the recomputed tail and shared-prefix joules are
        attributed once, to the request that actually computed them.
        ``extra`` (e.g. ``{"cached_tokens": ...}``) rides in the typed
        event for replay/analysis. Returns the :class:`TelemetryEvent`
        (its ``window`` index is what step spans reference for energy
        attribution), or None for a non-positive window."""
        if wall_s <= 0:
            return None
        self.source.set_step(n_tokens, wall_s, t0=self.session.cursor)
        tag_groups: Dict[str, List[Request]] = {}
        for idx, req in slot_to_req.items():
            tag_groups.setdefault(self.slot_tag(idx), []).append(req)
        event = TelemetryEvent(
            phase=phase, wall_s=wall_s, n_tokens=n_tokens,
            groups={tg: tuple(r.req_id for r in reqs)
                    for tg, reqs in tag_groups.items()},
            window=self.session.n_windows, t0=self.session.cursor,
            extra=dict(extra or {}))
        self.events.append(event)
        try:
            block = self.session.sample(wall_s,
                                        tags=[phase] + sorted(tag_groups))
        finally:
            self.source.clear()
        per_tag = block.split_energy(
            {tg: len(reqs) for tg, reqs in tag_groups.items()})
        for tg, reqs in tag_groups.items():
            share = per_tag.get(tg, 0.0) / len(reqs)
            if share:
                for r in reqs:
                    r.energy_j += share
        if self.metrics is not None:
            self.metrics.counter(
                "engine_energy_j", "board joules by phase").inc(
                block.energy_j(), phase=phase)
        return event

    def energy_stats(self) -> Dict:
        rep = self.session.report()
        out = {"energy_j": rep.energy_j, "energy_by_tag": dict(rep.by_tag)}
        if rep.counters:
            out["counters"] = dict(rep.counters)
        return out


# ---------------------------------------------------------------------------
# static-batch baseline


class ServeEngine:
    """Static batching: requests are padded into one fixed batch, prefilled
    together, and decoded in lock-step until the whole batch finishes. The
    baseline the continuous engine is benchmarked against."""

    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 telemetry: bool = True, dev: DeviceSpec = TPU_V5E,
                 prefill_buckets="auto", tracing: bool = True):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.buckets = resolve_buckets(prefill_buckets, max_seq, model)
        self.trace_stats = TraceStats()
        self.stats = ThroughputStats()
        self.metrics = MetricsRegistry()
        self.tracer = Tracer() if tracing else None
        self.pm = ServePowerModel(
            _count_params(params), dev=dev,
            cache_bytes=_cache_bytes(model, batch_size, max_seq))
        self.tel = (EngineTelemetry(self.pm, batch_size,
                                    metrics=self.metrics)
                    if telemetry else None)
        self._prefill = counting_jit(
            make_prefill_step(model, bucketed=bool(self.buckets)),
            "prefill", self.trace_stats, on_compile=self._on_compile)
        self._decode = counting_jit(make_decode_step(model), "decode",
                                    self.trace_stats,
                                    on_compile=self._on_compile)

    def _on_compile(self, name: str):
        if self.tel is not None:
            self.tel.session.count(f"compiles/{name}")
        self.metrics.counter("jit_compiles",
                             "XLA executables traced").inc(step=name)

    def _pad_prompts(self, reqs: List[Request]):
        """Left-pad prompts to the longest in the batch (position alignment:
        every row's last real token sits at ``s - 1``), then right-pad the
        whole batch to its bucket edge so prefill shapes stay bounded."""
        s = max(len(r.prompt) for r in reqs)
        sb = bucket_for(s, self.buckets) if self.buckets else s
        toks = np.zeros((self.batch_size, sb), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):s] = r.prompt   # left-pad
        return jnp.asarray(toks), s

    def serve(self, reqs: List[Request]) -> Dict:
        """One batch generation pass; returns stats."""
        assert reqs and len(reqs) <= self.batch_size
        pad = [Request(-1, np.zeros(1, np.int32), 0)
               for _ in range(self.batch_size - len(reqs))]
        tokens, s = self._pad_prompts(reqs + pad)
        caches = self.model.init_cache(self.batch_size, self.max_seq)
        win_cm = (self.tel.session.window() if self.tel
                  else contextlib.nullcontext())
        with win_cm as win:
            stats = self._serve_batch(reqs, tokens, s, caches)
        if self.tel:
            rep = win.report()      # this call's grid-aligned energy window
            stats["energy_j"] = rep.energy_j
            stats["energy_by_tag"] = dict(rep.by_tag)
        return stats

    def _serve_batch(self, reqs: List[Request], tokens, s: int,
                     caches) -> Dict:
        pf_cm = (self.tracer.span("prefill", track="engine",
                                  batch=len(reqs), bucket=tokens.shape[1])
                 if self.tracer is not None
                 else contextlib.nullcontext(NULL_SPAN))
        with pf_cm as psp:
            t0 = time.perf_counter()
            if self.buckets:
                logits, caches = self._prefill(self.params,
                                               {"tokens": tokens},
                                               jnp.int32(s), caches)
            else:
                logits, caches = self._prefill(self.params,
                                               {"tokens": tokens}, caches)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # dalek: allow[host-sync] one whole-batch fetch after prefill gates the first emit
            cur_host = np.asarray(cur)
            t_prefill = time.perf_counter() - t0
            # attribute only the true prompt tokens: left-pad, bucket tail,
            # and filler rows are compute the batch burns, not request
            # throughput
            n_prompt = sum(len(r.prompt) for r in reqs)
            self.stats.observe("prefill", n_prompt, t_prefill)
            self.metrics.histogram("prefill_step_s",
                                   "per-prefill wall seconds").observe(
                t_prefill)
            if self.tel:
                ev = self.tel.record("prefill", t_prefill, n_prompt,
                                     {i: r for i, r in enumerate(reqs)})
                if ev is not None:
                    psp.set("window", ev.window)

        for r in reqs:
            if r.max_new_tokens <= 0:
                r.done = True
                r.finish_reason = "length"

        n_decoded = 0
        t_dec = 0.0
        step = 0
        while not all(r.done for r in reqs):
            # emit the token sampled from the last logits (prefill or decode)
            for bi, r in enumerate(reqs):
                if r.done:
                    continue
                tok = int(cur_host[bi, 0])
                r.output.append(tok)
                n_decoded += 1
                if r.eos_id is not None and tok == r.eos_id:
                    r.done = True
                    r.finish_reason = "eos"
                elif r.n_generated >= r.max_new_tokens:
                    r.done = True
                    r.finish_reason = "length"
            if all(r.done for r in reqs):
                break           # nothing left: the last logits are not wasted
            active = {bi: r for bi, r in enumerate(reqs) if not r.done}
            step_cm = (self.tracer.span("decode_step", track="engine",
                                        active=len(active))
                       if self.tracer is not None
                       else contextlib.nullcontext(NULL_SPAN))
            with step_cm as ssp:
                td0 = time.perf_counter()
                cur, _, caches = self._decode(self.params, cur,
                                              jnp.int32(s + step), caches)
                # dalek: allow[host-sync] the designed once-per-step [B,1] fetch (EOS/budget checks)
                cur_host = np.asarray(cur)
                dt = time.perf_counter() - td0
                t_dec += dt
                step += 1
                # len(active), not batch_size: filler/finished rows decode
                # as dead weight and must not inflate throughput or touch
                # energy attribution (they own no slot tag)
                self.stats.observe("decode", len(active), dt)
                self.metrics.histogram(
                    "decode_step_s",
                    "fused decode step wall seconds").observe(dt)
                if self.tel:
                    ev = self.tel.record("decode", dt, len(active), active)
                    if ev is not None:
                        ssp.set("window", ev.window)

        self.metrics.counter("tokens_decoded").inc(n_decoded)
        for r in reqs:
            self.metrics.counter("requests_finished",
                                 "requests by finish reason").inc(
                reason=r.finish_reason or "eos")
            if self.tracer is not None:
                self.tracer.instant("finish", track=f"req{r.req_id}",
                                    req_id=r.req_id,
                                    finish_reason=r.finish_reason)
        return {
            "prefill_s": t_prefill,
            "decode_s": t_dec,
            "decode_steps": step,
            "tokens_decoded": n_decoded,
            "prompt_tokens": n_prompt,
            "decode_tok_per_s": n_decoded / t_dec if t_dec else 0.0,
            "prefill_compiles": self.trace_stats.compiles("prefill"),
            "decode_compiles": self.trace_stats.compiles("decode"),
            "compiles": self.trace_stats.snapshot(),
        }


# ---------------------------------------------------------------------------
# continuous batching


class ContinuousEngine:
    """Continuous batching over one shared per-slot state store.

    Requests queue up (``submit``) and ``run`` drains them: free slots are
    filled via single-slot prefills (other slots keep their in-flight
    state), every decode step advances *all* active slots with one fused
    jitted call (per-slot positions, sampling inside jit, one [B,1] host
    fetch), and a slot is recycled the moment its request hits EOS or its
    token budget — so late requests join mid-decode instead of waiting for
    the batch to drain.

    All per-slot state handling (paged KV pool, contiguous window rings,
    recurrent carried state) lives behind ``self.adapter``
    (``serve.state.CacheAdapter``), selected by the model family's declared
    ``ServingCaps`` — the engine body is family-agnostic.
    """

    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 telemetry: bool = True, dev: DeviceSpec = TPU_V5E,
                 power_cap_w: Optional[float] = None, greedy: bool = True,
                 prefill_buckets="auto", kv_block_size="auto",
                 prefix_cache: bool = True,
                 kv_pool_blocks: Optional[int] = None,
                 tracing: bool = True):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.trace_stats = TraceStats()
        # the family-declared backend: paged KV (flat transformers), window
        # rings (gemma3) / contiguous fallback, or recurrent carried state.
        # "auto" arguments degrade where the family can't honor them;
        # explicit requests on an incapable family raise early.
        self.adapter = make_adapter(
            model, params, batch_size=batch_size, max_seq=max_seq,
            prefill_buckets=prefill_buckets, kv_block_size=kv_block_size,
            prefix_cache=prefix_cache, kv_pool_blocks=kv_pool_blocks,
            greedy=greedy, trace_stats=self.trace_stats,
            on_compile=self._on_compile)
        self.family = model.cfg.family
        self.pm = ServePowerModel(
            _count_params(params), dev=dev,
            cache_bytes=_cache_bytes(model, batch_size, max_seq))
        self.stats = ThroughputStats()
        self.admission = AdmissionController(self.pm, power_cap_w, self.stats)
        self.queue = RequestQueue()
        self.slots = SlotManager(batch_size, max_seq)
        # observability: registry-backed run stats + request-lifecycle spans
        # (queued -> admitted -> prefill -> decode -> finish) and per-step
        # engine spans carrying window refs for the energy-attributed
        # timeline export (repro.obs.export)
        self.metrics = MetricsRegistry()
        self.tracer = Tracer() if tracing else None
        self._req_spans: Dict[int, object] = {}   # req_id -> open span
        self.tel = (EngineTelemetry(self.pm, batch_size,
                                    metrics=self.metrics)
                    if telemetry else None)
        # every telemetry event / engine-step span carries the backend and
        # family so Perfetto timelines and .dkt replay can tell paged,
        # ring, and recurrent slots apart
        self._slot_attrs = {"adapter": self.adapter.kind,
                            "family": self.family}
        self.dvfs = self.admission.apply_dvfs(batch_size)
        self.finished: List[Request] = []

    # attribute aliases: the adapter owns the state, but benches/tests/
    # launchers address it through the engine
    @property
    def buckets(self):
        return self.adapter.buckets

    @property
    def block_size(self):
        return self.adapter.block_size

    @property
    def pages(self):
        return self.adapter.pages

    @property
    def prefix(self):
        return self.adapter.prefix

    @property
    def caches(self):
        return self.adapter.caches

    def _on_compile(self, name: str):
        if self.tel is not None:
            self.tel.session.count(f"compiles/{name}")
        self.metrics.counter("jit_compiles",
                             "XLA executables traced").inc(step=name)

    # -- request intake ------------------------------------------------------

    def submit(self, req: Request):
        """Queue a request. The prompt must leave at least one decode
        position; a generation budget that would overrun the cache is
        accepted — the request finishes early with reason "capacity" when
        it hits the last position (the old behavior silently clamped the
        position and overwrote the final KV entry every step)."""
        if len(req.prompt) + 1 > self.max_seq:
            raise ValueError(
                f"request {req.req_id}: prompt of {len(req.prompt)} leaves "
                f"no decode position with max_seq={self.max_seq}")
        if self.adapter.caps.needs_frames and req.frames is None:
            raise ValueError(
                f"request {req.req_id}: family '{self.family}' is "
                "encoder-decoder — attach encoder frames "
                "(Request(frames=[enc_seq, d_model])) so the first prefill "
                "chunk can build the cross-attention cache")
        self.queue.push(req)
        self.metrics.counter("requests_submitted").inc()
        if self.tracer is not None:
            # lifecycle span 1: time on the queue. Ended (and chained into
            # prefill/decode spans) at admission, or closed with the shed
            # reason — _close_req_span owns the hand-off.
            self._req_spans[req.req_id] = self.tracer.begin(
                "queued", track=f"req{req.req_id}", req_id=req.req_id,
                prompt_tokens=len(req.prompt),
                max_new_tokens=req.max_new_tokens)

    def _close_req_span(self, req: Request, **attrs):
        """End the request's open lifecycle span (queued or decode)."""
        sp = self._req_spans.pop(req.req_id, None)
        if sp is not None:
            sp.update(**attrs)
            sp.end()

    # -- slot lifecycle ------------------------------------------------------

    def _finish(self, slot, reason: str):
        req = slot.req
        req.done = True
        req.finish_reason = reason
        self.finished.append(req)
        self.metrics.counter("requests_finished",
                             "requests by finish reason").inc(reason=reason)
        self._close_req_span(req, finish_reason=reason,
                             tokens=len(req.output), energy_j=req.energy_j)
        if self.tracer is not None:
            self.tracer.instant("finish", track=f"req{req.req_id}",
                                req_id=req.req_id, finish_reason=reason)
        # release/reset the slot's backend state (page refs dropped and
        # scrub-queued, or the row reset) so the next occupant starts clean
        self.adapter.free_slot(slot.index)
        self.slots.release(slot)

    def _emit(self, slot, tok: int):
        req = slot.req
        req.output.append(tok)
        self.metrics.counter("tokens_decoded").inc()
        if req.eos_id is not None and tok == req.eos_id:
            self._finish(slot, "eos")
        elif req.n_generated >= req.max_new_tokens:
            self._finish(slot, "length")

    def _shed_stale(self):
        """TTL shedding: a queued request's predicted wait is the remaining
        decode budget ahead of it (active slots + queue positions in front)
        cleared at the measured decode rate, plus the queued prompts ahead
        cleared at the measured prefill rate. Prompts are priced net of the
        span the prefix cache is expected to serve — a warm shared prefix
        costs no prefill compute, and pricing it gross sheds requests that
        would easily meet their TTL."""
        if not self.queue:
            return
        ahead = sum(s.req.max_new_tokens - s.req.n_generated
                    for s in self.slots.active_slots())
        ahead_prefill = 0
        for req in self.queue.snapshot():
            if self.admission.should_shed(req, ahead, ahead_prefill):
                self.queue.shed(req)     # shed() drops it from the queue too
                self.metrics.counter("requests_shed",
                                     "sheds by reason").inc(reason="ttl")
                self._close_req_span(req, finish_reason=req.finish_reason)
            else:
                # a queued request costs its prompt (prefill) AND its
                # budget (decode) — tracked separately so each phase is
                # priced at its own measured rate
                ahead += req.max_new_tokens
                ahead_prefill += max(
                    0, len(req.prompt) - self.adapter.expected_cached(req))

    def _admit(self):
        """Fill free slots from the queue, subject to the admission policy
        (power cap, TTL) and — when paged — page availability: a request is
        admitted only if the pool can back its worst-case footprint, else
        admission defers until active requests free pages."""
        self._shed_stale()
        while self.queue and self.slots.free_slots():
            if self.admission.max_slots(self.batch_size) == 0:
                while self.queue:        # cap below even 1-slot power: shed
                    req = self.queue.pop()
                    self.queue.shed(req, "shed-cap")
                    self.metrics.counter("requests_shed",
                                         "sheds by reason").inc(reason="cap")
                    self._close_req_span(req, finish_reason="shed-cap")
                break
            if not self.admission.admit(self.slots.n_active, self.batch_size):
                break                     # defer under the power cap
            if not self.adapter.can_admit(self.queue.peek()):
                break                     # defer until backend capacity frees
            req = self.queue.pop()
            if req.max_new_tokens <= 0:
                req.done = True
                req.finish_reason = "length"
                self.finished.append(req)
                self.metrics.counter(
                    "requests_finished",
                    "requests by finish reason").inc(reason="length")
                self._close_req_span(req, finish_reason="length", tokens=0)
                continue
            self._prefill_into(self.slots.free_slots()[0], req)

    def _prefill_into(self, slot, req: Request):
        self._close_req_span(req)        # queued span ends at admission
        psp = NULL_SPAN
        if self.tracer is not None:
            self.tracer.instant("admitted", track=f"req{req.req_id}",
                                req_id=req.req_id, slot=slot.index)
            psp = self.tracer.begin("prefill", track=f"req{req.req_id}",
                                    req_id=req.req_id, slot=slot.index,
                                    **self._slot_attrs)
        t0 = time.perf_counter()
        out = self.adapter.prefill(slot.index, req)
        if out.first_token is None:
            # backend dry (undersized page pool): the adapter already
            # dropped the slot's resources; finish the request here
            req.done = True
            req.finish_reason = "pages"
            self.finished.append(req)
            self.metrics.counter("requests_finished",
                                 "requests by finish reason").inc(
                reason="pages")
            psp.update(finish_reason="pages")
            psp.end()
            return
        first, cached, tail_len = (out.first_token, out.cached_tokens,
                                   out.computed_tokens)
        dt = time.perf_counter() - t0
        req.prefill_s = dt
        req.cached_prompt_tokens = cached
        self.metrics.histogram("prefill_step_s",
                               "per-prefill wall seconds").observe(dt)
        self.metrics.counter(
            "prefill_tokens_computed",
            "prompt tokens actually run (cache hits and bucket pad "
            "excluded)").inc(tail_len)
        # throughput + energy see only the *computed* tail: cached tokens
        # burn no board time, so shared-prefix joules are attributed once —
        # to the request that actually ran the prefill
        self.stats.observe("prefill", tail_len, dt)
        ev = None
        if self.tel:
            extra = dict(self._slot_attrs)
            if cached:
                extra["cached_tokens"] = cached
            ev = self.tel.record("prefill", dt, tail_len, {slot.index: req},
                                 extra=extra)
        psp.update(bucket=(bucket_for(tail_len, self.buckets)
                           if self.buckets else tail_len),
                   cached_tokens=cached, computed_tokens=tail_len,
                   window=ev.window if ev is not None else -1)
        psp.end()
        self.slots.assign(slot, req, first)
        if self.tracer is not None:
            # lifecycle span 3: decode residency — closed by _finish with
            # the finish reason and attributed joules
            self._req_spans[req.req_id] = self.tracer.begin(
                "decode", track=f"req{req.req_id}", req_id=req.req_id,
                slot=slot.index)
        self._emit(slot, first)   # prefill samples the first token

    def _decode_once(self):
        # pre-step backend bookkeeping (paged: back every active write
        # position, COW defensively-shared blocks); slots the backend can
        # no longer cover finish "pages"
        for s in self.adapter.begin_step(list(self.slots.active_slots())):
            self._finish(s, "pages")
        active = self.slots.active_slots()
        if not active:
            return
        # per-step engine span: queue depth + pool occupancy gauges ride on
        # it, and the step's sample window is referenced for the timeline's
        # exact joule partition
        depth = len(self.queue)
        free, evictable = self.adapter.pool_gauges()
        self.metrics.gauge("queue_depth").set(depth)
        if self.pages is not None:
            self.metrics.gauge("kv_free_blocks").set(free)
        if self.prefix is not None:
            self.metrics.gauge("kv_evictable_blocks").set(evictable)
        step_cm = (self.tracer.span(
            "decode_step", track="engine", active=len(active),
            queue_depth=depth, free_blocks=free, evictable_blocks=evictable,
            **self._slot_attrs)
            if self.tracer is not None else contextlib.nullcontext(NULL_SPAN))
        with step_cm as ssp:
            tokens = jnp.asarray(self.slots.batch_tokens())
            pos = jnp.asarray(self.slots.batch_positions())
            t0 = time.perf_counter()
            next_tok = self.adapter.decode_step(tokens, pos)
            # dalek: allow[host-sync] the designed once-per-step [B,1] fetch (EOS/budget checks)
            toks = np.asarray(next_tok)
            dt = time.perf_counter() - t0
            self.metrics.histogram("decode_step_s",
                                   "fused decode step wall seconds").observe(dt)
            self.stats.observe("decode", len(active), dt)
            if self.tel:
                ev = self.tel.record("decode", dt, len(active),
                                     {s.index: s.req for s in active},
                                     extra=dict(self._slot_attrs))
                if ev is not None:
                    ssp.set("window", ev.window)
        for s in active:
            s.req.decode_steps += 1
            tok = int(toks[s.index, 0])
            self.slots.advance(s, tok)
            self._emit(s, tok)
            # the clamp fix: a request that filled the cache finishes here
            # instead of silently overwriting the last KV position forever
            if s.req is not None and self.slots.at_capacity(s):
                self._finish(s, "capacity")

    # -- driver --------------------------------------------------------------

    def run(self) -> Dict:
        """Drain the queue; returns aggregate + per-request stats."""
        self.adapter.ensure_ready()       # lazy state allocation
        while True:
            self._admit()
            if self.slots.n_active == 0:
                break
            self._decode_once()
        # run stats are read back out of the metrics registry — the same
        # store --metrics-json snapshots and prometheus() exposes
        n_emitted = int(self.metrics.counter("tokens_decoded").total())
        dec = self.metrics.histogram("decode_step_s")
        pre = self.metrics.histogram("prefill_step_s")
        stats = {
            "completed": len(self.finished),
            "shed": self.queue.n_shed,
            "tokens_decoded": n_emitted,
            "prefill_s": pre.sum(),
            "decode_s": dec.sum(),
            "decode_steps": dec.count(),
            "decode_tok_per_s": (n_emitted / dec.sum()
                                 if dec.sum() else 0.0),
            "prefills": self.slots.n_assigned,
            "prompt_tokens": self.slots.n_prefill_tokens,
            "prefill_tokens_computed": int(self.metrics.counter(
                "prefill_tokens_computed").total()),
            "slots_recycled": self.slots.n_released,
            "peak_active": self.slots.peak_active,
            "dvfs_f_ghz": self.dvfs.f_ghz if self.dvfs else None,
            "prefill_compiles": self.trace_stats.compiles("prefill"),
            "decode_compiles": self.trace_stats.compiles("decode"),
            # every executable family the engine traced — incl. the state
            # maintenance ops (reset_slot / state_scatter / zero_blocks /
            # copy_block)
            "compiles": self.trace_stats.snapshot(),
            "prefill_buckets": list(self.buckets) if self.buckets else None,
            "adapter": self.adapter.kind,
            "family": self.family,
        }
        stats.update(self.adapter.run_stats())   # kv_block_size, kv_pages, …
        if self.tel:
            stats.update(self.tel.energy_stats())
        return stats

    def serve(self, reqs: List[Request]) -> Dict:
        """Convenience: submit all and drain."""
        for r in reqs:
            self.submit(r)
        return self.run()

    def reset_metrics(self):
        """Clear counters, queue state, and samples (benchmark warmup);
        jit caches and the KV buffer survive — freed slots are always
        re-prefilled before reuse, so stale KV is never read.
        ``trace_stats`` is intentionally NOT cleared: compile counts track
        the engine's lifetime executable set (the thing the bucket bound
        promises), while the telemetry session's ``compiles/*`` counters
        reset with the samples they annotate."""
        self.finished = []
        self.metrics.clear()
        if self.tracer is not None:
            self.tracer.clear()
        self._req_spans = {}
        self.queue = RequestQueue()
        self.slots = SlotManager(self.batch_size, self.max_seq)
        # backend statistics reset (prefix trie cleared, pool stats zeroed):
        # a benchmark's measured phase must not reap hits the warmup planted
        # (the warmup's *compiles* are exactly what reset keeps — same
        # policy as trace_stats below)
        self.adapter.reset_metrics()
        if self.tel:
            self.tel.session.reset()
            self.tel.events = []       # event log tracks the sample stream
