"""Serving engine integration: batching, stop handling, energy attribution,
and consistency between engine decode and direct model calls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_smoke("granite-20b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))
    return cfg, ServeEngine(model, params, batch_size=4, max_seq=48)


def test_serve_batch_generates(engine):
    cfg, eng = engine
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=6) for i in range(3)]
    stats = eng.serve(reqs)
    for r in reqs:
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    assert stats["tokens_decoded"] > 0
    assert stats["decode_tok_per_s"] > 0


def test_serve_respects_per_request_limits(engine):
    cfg, eng = engine
    rng = np.random.default_rng(1)
    reqs = [
        Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=2),
        Request(1, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=7),
    ]
    eng.serve(reqs)
    assert len(reqs[0].output) == 2
    assert len(reqs[1].output) == 7


def test_serve_energy_tags(engine):
    cfg, eng = engine
    rng = np.random.default_rng(2)
    reqs = [Request(9, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3)]
    stats = eng.serve(reqs)
    assert "prefill" in stats["energy_by_tag"]
    assert "decode" in stats["energy_by_tag"]
    # every sample is taken inside exactly one phase tag
    phases = (stats["energy_by_tag"]["prefill"]
              + stats["energy_by_tag"]["decode"])
    assert abs(stats["energy_j"] - phases) <= 1e-6 + 0.01 * stats["energy_j"]
    # per-request attribution flows through the slot tags
    assert reqs[0].energy_j > 0.0


def test_serve_cli_runs():
    from repro.launch.serve import main
    stats = main(["--arch", "qwen3-32b", "--smoke", "--requests", "2",
                  "--prompt-len", "8", "--max-new", "4", "--max-seq", "32",
                  "--batch", "2"])
    assert stats["tokens_decoded"] >= 4
