"""Config system: model architecture configs + assigned input shapes.

Every assigned architecture gets one file in this package exporting CONFIG
(the full published config) and SMOKE_CONFIG (a reduced same-family config for
CPU smoke tests). ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering all assigned families."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention pattern ---
    qk_norm: bool = False
    sliding_window: int = 0        # 0 = full attention
    local_global_period: int = 0   # gemma3: every Nth layer is global, rest local
    rope_theta: float = 10_000.0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0         # leading dense layers (deepseek-moe style)
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0             # mamba2 state size
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    slstm_every: int = 0           # xlstm: every Nth block is sLSTM (rest mLSTM)
    attn_every: int = 0            # zamba2: shared attention block every Nth layer

    # --- encoder-decoder (whisper) ---
    encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500            # whisper encoder frames (stub frontend)

    # --- modality stub ---
    frontend_stub: bool = False    # vlm/audio: inputs are precomputed embeddings
    stub_prefix_len: int = 256     # vlm: number of patch-embedding tokens

    # --- numerics ---
    dtype: str = "bfloat16"        # compute dtype
    param_dtype: str = "float32"   # master/storage dtype for training

    # --- mesh adaptation ---
    # Query heads padded up to a multiple of the TP axis. Padded heads get
    # zero-initialized wq rows and wo columns, making them exact no-ops
    # (function-preserving); 0 = no padding.
    pad_q_heads: int = 0

    # --- notes ---
    subquadratic: bool = False     # eligible for long_500k
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_heads(self) -> int:
        """Effective query-head count (after TP padding)."""
        return max(self.pad_q_heads, self.num_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def adapt_for_mesh(self, model_axis_size: int) -> "ModelConfig":
        """Pad query heads to a multiple of the TP axis when needed.

        GQA ratio must stay integral: padded H must also be a multiple of
        num_kv_heads. kv heads are never padded (zero keys would perturb the
        softmax); indivisible kv heads are handled by cache sequence
        sharding instead (see launch.dryrun.serve_rules).
        """
        h = self.num_heads
        if h % model_axis_size == 0:
            return self
        import math
        step = (model_axis_size * self.num_kv_heads
                // math.gcd(model_axis_size, self.num_kv_heads))
        padded = ((h + step - 1) // step) * step
        if padded > 1.5 * h:
            # padding overhead too high (e.g. whisper 12H -> 48H on TP16):
            # stay unpadded; attention is replicated over the model axis,
            # which is acceptable for small models.
            return self
        return self.replace(pad_q_heads=padded)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

ARCH_NAMES = [
    "granite-20b",
    "deepseek-coder-33b",
    "gemma3-27b",
    "qwen3-32b",
    "xlstm-1.3b",
    "internvl2-76b",
    "deepseek-moe-16b",
    "moonshot-v1-16b-a3b",
    "zamba2-1.2b",
    "whisper-small",
]


def _module_for(name: str):
    mod_name = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get(name: str) -> ModelConfig:
    return _module_for(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module_for(name).SMOKE_CONFIG


def shape_cells(arch: str) -> Tuple[str, ...]:
    """Which assigned shapes run for this arch (documented skips in DESIGN.md)."""
    cfg = get(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        cells.append("long_500k")
    return tuple(cells)


def all_cells():
    for arch in ARCH_NAMES:
        for shape in shape_cells(arch):
            yield arch, shape
