"""Pure-jnp oracle for dpa_matmul."""
import jax.numpy as jnp


def matmul(a, b, variant="dpa2"):
    if variant == "fma_f32":
        return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    if variant == "dpa2":
        return jnp.dot(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    if variant == "dpa4":
        return jnp.dot(a.astype(jnp.int8), b.astype(jnp.int8),
                       preferred_element_type=jnp.int32)
    raise ValueError(variant)
