"""GPIO region tagging (paper Sec. 4.1/4.3).

The main board has eight GPIO inputs driven by the measured node, so running
code can tag samples with the active code segment ("measure the consumption
of a specific function"). We reproduce the exact constraint: at most 8
concurrent binary channels; a tag is a named channel raised/lowered around a
code region, and samples record the set of channels high at sample time.

A GPIO line is only occupied while its tag is high: lowering a tag releases
the line for reuse, so any number of *distinct* tag names may be used over a
run as long as no more than 8 are ever high at once (the hardware limit).

Lookups go through an incrementally compiled interval index (``TagIndex``):
each event appends one epoch (a snapshot of the 8-line state plus the
line->name map), and ``active_at`` is a binary search into the epoch
timeline instead of an O(events) replay of the whole log — the columnar
sampling path queries whole timestamp arrays against it at once.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

N_GPIO = 8


class TagIndex:
    """Immutable epoch timeline snapshot of a :class:`TagBus` event log.

    Epoch ``k`` covers ``(times[k], times[k+1]]``: ``states[k]`` is the
    8-line bitmask after event ``k`` was applied and ``maps[k]`` the
    ``line -> name`` mapping in force. Times at an event boundary resolve to
    the *later* epoch (an event at exactly ``t`` is applied at ``t``),
    matching the original replay semantics.
    """

    def __init__(self, times: np.ndarray, states: np.ndarray,
                 maps: List[Mapping[int, str]], n: int):
        # zero-copy views of the bus's append-only buffers: entries below
        # ``n`` never mutate, so the snapshot stays consistent even as the
        # bus keeps logging (a buffer regrow leaves old views intact)
        self._times = times[:n]
        self._states = states[:n]
        self._maps = maps                       # shared, append-only list
        self._n = n                             # snapshot length

    def __len__(self) -> int:
        return self._n

    def epoch_at(self, t: float) -> int:
        """Index of the epoch covering time ``t`` (-1 before any event)."""
        return int(np.searchsorted(self._times, t, side="right")) - 1

    def epochs_at(self, t: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`epoch_at` for a sorted-or-not time array."""
        return np.searchsorted(self._times, t, side="right").astype(np.int64) - 1

    def states_at(self, t: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(uint8 bitmask per time, epoch per time) for an array of times."""
        epochs = self.epochs_at(t)
        if not self._n:
            return np.zeros(epochs.shape, np.uint8), epochs
        bits = np.where(epochs >= 0, self._states[np.clip(epochs, 0, None)],
                        np.uint8(0)).astype(np.uint8)
        return bits, epochs

    def map_at(self, epoch: int) -> Mapping[int, str]:
        """line -> name mapping in force during ``epoch`` ({} before t0)."""
        if epoch < 0 or epoch >= self._n:
            return {}
        return self._maps[epoch]

    def active_at(self, t: float) -> Tuple[str, ...]:
        k = self.epoch_at(t)
        if k < 0:
            return ()
        state, m = self._states[k], self._maps[k]
        return tuple(sorted(m[i] for i in m if state & (1 << i)))


class TagBus:
    """The 8-channel GPIO bus between the node and its main board."""

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self._lock = threading.Lock()
        self._channels: Dict[str, int] = {}     # name -> gpio index (while high)
        self._high: Dict[int, str] = {}         # gpio index -> name
        self._events: List[Tuple[float, int, str, bool]] = []
        # incrementally compiled epoch timeline (one entry per event):
        # growable numpy buffers (capacity-doubled) so TagIndex snapshots
        # are zero-copy views and compilation is amortized O(1) per event
        self._idx_times = np.zeros(16, np.float64)
        self._idx_states = np.zeros(16, np.uint8)
        self._idx_maps: List[Mapping[int, str]] = []
        self._idx_high: Dict[int, str] = {}     # replay cursor state
        self._compiled_upto = 0
        self._index_cache: Optional[TagIndex] = None

    def _alloc(self, name: str) -> int:
        if name in self._channels:
            return self._channels[name]
        if len(self._channels) >= N_GPIO:
            raise RuntimeError(
                f"all {N_GPIO} GPIO tag channels in use (paper HW limit)")
        idx = next(i for i in range(N_GPIO)
                   if i not in self._channels.values())
        self._channels[name] = idx
        return idx

    def raise_(self, name: str):
        with self._lock:
            idx = self._alloc(name)
            self._high[idx] = name
            self._events.append((self._clock(), idx, name, True))
            self._index_cache = None

    def lower(self, name: str):
        with self._lock:
            idx = self._channels.get(name)
            if idx is not None and idx in self._high:
                del self._high[idx]
                # release the GPIO line: only concurrent tags occupy channels
                del self._channels[name]
                self._events.append((self._clock(), idx, name, False))
                self._index_cache = None

    # -- compiled interval index --------------------------------------------

    def _compile_locked(self):
        """Extend the epoch timeline with any events logged since the last
        compile (amortized O(1) per event; no full-log replay)."""
        need = len(self._events)
        if need > self._idx_times.shape[0]:
            cap = max(2 * self._idx_times.shape[0], need)
            self._idx_times = np.concatenate(
                [self._idx_times, np.zeros(cap - self._idx_times.shape[0])])
            self._idx_states = np.concatenate(
                [self._idx_states,
                 np.zeros(cap - self._idx_states.shape[0], np.uint8)])
        for k in range(self._compiled_upto, need):
            et, idx, name, up = self._events[k]
            if up:
                self._idx_high[idx] = name
            else:
                self._idx_high.pop(idx, None)
            state = 0
            for i in self._idx_high:
                state |= 1 << i
            self._idx_times[k] = et
            self._idx_states[k] = state
            self._idx_maps.append(dict(self._idx_high))
        self._compiled_upto = need

    def index(self) -> TagIndex:
        """Compiled epoch timeline for interval/bitmask lookups (cached
        until the next raise/lower)."""
        with self._lock:
            if self._index_cache is None:
                self._compile_locked()
                self._index_cache = TagIndex(self._idx_times, self._idx_states,
                                             self._idx_maps,
                                             n=len(self._idx_maps))
            return self._index_cache

    def active_at(self, t: float) -> Tuple[str, ...]:
        """Tags high at time t (binary search into the epoch timeline)."""
        return self.index().active_at(t)

    def active_now(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._high.values()))

    @contextlib.contextmanager
    def tag(self, name: str):
        """``with bus.tag("fwd"): ...`` — energy attribution for a region."""
        self.raise_(name)
        try:
            yield
        finally:
            self.lower(name)

    def intervals(self, name: str) -> List[Tuple[float, Optional[float]]]:
        """(start, end) intervals for a tag; end=None if still high."""
        out: List[Tuple[float, Optional[float]]] = []
        start = None
        with self._lock:
            events = list(self._events)
        for et, _, n, up in events:
            if n != name:
                continue
            if up and start is None:
                start = et
            elif not up and start is not None:
                out.append((start, et))
                start = None
        if start is not None:
            out.append((start, None))
        return out
