"""shard_map EP all-to-all MoE == GSPMD MoE (multi-device parity).

Run under a multi-device env:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest ...
Skipped on single-device runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.common import ParamBuilder
from repro.models.moe import moe_apply, moe_init
from repro.parallel.sharding import Sharder

pytestmark = pytest.mark.skipif(jax.device_count() < 8,
                                reason="needs 8 host devices")


def _setup(rules=None):
    # capacity factor large enough that NO tokens drop on either path: the
    # two implementations then compute the identical function (drop PATTERNS
    # legitimately differ between per-rank and per-group capacity)
    cfg = configs.get_smoke("deepseek-moe-16b").replace(capacity_factor=8.0)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    pb = ParamBuilder(jax.random.key(0))
    moe_init(pb, cfg, None)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)) * 0.3, jnp.float32)
    return cfg, mesh, pb.params, x


def test_shard_map_matches_gspmd():
    cfg, mesh, params, x = _setup()
    shd = Sharder(mesh)
    with mesh:
        y_ref, aux_ref = jax.jit(
            lambda p, v: moe_apply(v, p, cfg, shd, impl="gspmd"))(params, x)
        y_sm, aux_sm = jax.jit(
            lambda p, v: moe_apply(v, p, cfg, shd, impl="shard_map"))(params, x)
    # with a generous capacity factor, no tokens drop in either path:
    # outputs must match exactly (same routing, same experts)
    np.testing.assert_allclose(np.asarray(y_sm, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_shard_map_grads_match():
    cfg, mesh, params, x = _setup()
    shd = Sharder(mesh)

    # NOTE: the aux load-balance loss is excluded — it is an estimator over
    # routing subsets (per-group for gspmd, per-rank for shard_map), so its
    # gradient legitimately differs in granularity. The MODEL function and
    # its gradients must match exactly.
    def loss(impl):
        def f(p, v):
            y, aux = moe_apply(v, p, cfg, shd, impl=impl)
            return jnp.sum(jnp.square(y.astype(jnp.float32)))
        return f

    with mesh:
        g_ref = jax.jit(jax.grad(loss("gspmd")))(params, x)
        g_sm = jax.jit(jax.grad(loss("shard_map")))(params, x)
    for k in ("w_gate", "w_up", "w_down", "router"):
        np.testing.assert_allclose(
            np.asarray(g_sm[k], np.float32), np.asarray(g_ref[k], np.float32),
            rtol=5e-3, atol=5e-3)
