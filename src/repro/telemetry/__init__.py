"""Unified telemetry API for the paper's energy-monitoring platform.

Everything outside this package consumes the measurement pipeline (INA228
probes -> PIC18 main board -> 8-line GPIO tag bus, paper Sec. 4) through
:class:`MonitorSession`:

- :mod:`~repro.telemetry.source` — what the probes measure (``ModelSource``
  for roofline/DVFS traces, ``MutableSource`` for host-updated power,
  ``TraceSource`` for recorded arrays);
- :mod:`~repro.telemetry.session` — ``MonitorSession`` facade: region
  tagging, grid-aligned sampling windows, typed ``EnergyReport``;
- :mod:`~repro.telemetry.samples` — columnar ``SampleBlock`` streams
  (numpy columns + per-sample GPIO bitmask) with vectorized energy
  reductions and a lazy legacy ``Sample`` view.
"""
from repro.core.probe import (AVG_N, MILLIWATT, RAW_SPS, REPORT_SPS,
                              ProbeConfig, read_vectorized)
from repro.telemetry.samples import SampleBlock, SampleView, read_board_blocks
from repro.telemetry.session import EnergyReport, MonitorSession, Window
from repro.telemetry.source import (ModelSource, MutableSource, PowerSource,
                                    TraceExhausted, TraceSource, constant)

__all__ = [
    "MonitorSession", "Window", "EnergyReport",
    "SampleBlock", "SampleView", "read_board_blocks",
    "PowerSource", "ModelSource", "MutableSource", "TraceSource",
    "TraceExhausted", "constant",
    # platform constants / probe config re-exported for consumers
    "ProbeConfig", "read_vectorized",
    "AVG_N", "MILLIWATT", "RAW_SPS", "REPORT_SPS",
]
