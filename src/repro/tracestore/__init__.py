"""Trace store: durable, replayable power recordings (``.dkt`` files).

The telemetry platform (``repro.telemetry``) measures at 1000 SPS with
milliwatt resolution, but a measurement that dies with the process is a
demo, not an instrument. This package persists ``SampleBlock`` streams
bit-exactly and replays them deterministically:

- :mod:`~repro.tracestore.format` — the chunked, versioned ``.dkt`` binary
  layout (columnar payloads, interned tag table, indexed footer);
- :mod:`~repro.tracestore.io` — ``TraceWriter`` / mmap-backed
  ``TraceReader`` with O(log chunks) time seeks;
- :mod:`~repro.tracestore.recorder` — ``ClusterRecorder`` (one session per
  topology node, one probe per chip, shared clock) and
  ``record_session``/``record_engine`` for live-run export;
- :mod:`~repro.tracestore.replay` — deterministic replay: bit-exact
  session reconstruction (``replay_attribution``), admission-policy
  regression (``replay_policy`` -> ``ReplayReport``), and recorded-power
  cluster scheduling (``replay_cluster``).
"""
from repro.tracestore.format import (ChunkInfo, TraceFormatError, VERSION)
from repro.tracestore.io import TraceReader, TraceWriter, slice_block
from repro.tracestore.recorder import (ClusterRecorder, record_engine,
                                       record_session)
from repro.tracestore.replay import (ClusterJobResult, EnergyTimeline,
                                     PolicyResult, ReplayReport,
                                     ReplayRequest, node_power_fn,
                                     rebuild_sources, replay,
                                     replay_attribution, replay_cluster,
                                     replay_policy, replay_session)

__all__ = [
    "VERSION", "ChunkInfo", "TraceFormatError",
    "TraceReader", "TraceWriter", "slice_block",
    "ClusterRecorder", "record_session", "record_engine",
    "ReplayRequest", "PolicyResult", "ClusterJobResult", "ReplayReport",
    "EnergyTimeline",
    "rebuild_sources", "node_power_fn", "replay", "replay_attribution",
    "replay_cluster", "replay_policy", "replay_session",
]
