"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness, plus prefill/decode agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import abstract_params, build_model


def _batch(cfg, b=2, s=16, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeddings"] = jnp.asarray(
            rng.normal(size=(b, cfg.stub_prefix_len, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, attn_impl="blocked", q_block=8)
    params, _ = model.init(jax.random.key(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    logits, aux = jax.jit(model.forward)(params, batch)
    extra = cfg.stub_prefix_len if cfg.family == "vlm" else 0
    assert logits.shape == (b, s + extra, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), arch
    assert jnp.isfinite(jnp.asarray(aux, jnp.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_train_step_smoke(arch):
    """One SGD step: grads exist, are finite, loss decreases over 3 steps."""
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, attn_impl="blocked", q_block=8)
    params, _ = model.init(jax.random.key(0))
    batch = _batch(cfg, 2, 16)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, aux = model.forward(p, batch)
        logits = logits[:, -labels.shape[1]:]
        from repro.models.common import softmax_xent
        return softmax_xent(logits, labels) + 0.01 * aux

    step = jax.jit(lambda p: (loss_fn(p), jax.grad(loss_fn)(p)))
    losses = []
    for _ in range(3):
        loss, grads = step(params)
        losses.append(float(loss))
        gnorm = jax.tree.reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
            grads, 0.0)
        assert jnp.isfinite(gnorm) and gnorm > 0, arch
        params = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                              params, grads)
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_prefill_decode_matches_forward(arch):
    """Greedy next-token from (prefill + decode_step) == from full forward."""
    cfg = configs.get_smoke(arch)
    model = build_model(cfg, attn_impl="blocked", q_block=8)
    params, _ = model.init(jax.random.key(1))
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    max_seq = 32

    caches = model.init_cache(b, max_seq)
    logits_pf, caches = jax.jit(model.prefill)(params, batch, caches)
    # full forward logits at the last prompt position must agree
    logits_full, _ = jax.jit(model.forward)(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_pf[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=0.1, atol=0.15)

    # one decode step stays finite and has the right shape
    tok = jnp.argmax(logits_pf, axis=-1).astype(jnp.int32)
    pos = s + (cfg.stub_prefix_len if cfg.family == "vlm" else 0)
    logits_d, caches = jax.jit(model.decode_step)(
        params, tok, jnp.int32(pos), caches)
    assert logits_d.shape == (b, 1, cfg.vocab_size)
    assert jnp.isfinite(logits_d.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_abstract_params_match_concrete(arch):
    cfg = configs.get_smoke(arch)
    model = build_model(cfg)
    params, axes = model.init(jax.random.key(0))
    sds, axes2 = abstract_params(model)
    concrete_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), params)
    abstract_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), sds)
    assert concrete_shapes == abstract_shapes
    assert axes == axes2
    # every param has an axes entry of matching rank
    is_axes = lambda x: isinstance(x, tuple) and all(
        i is None or isinstance(i, str) for i in x)
    jax.tree.map(lambda a, p: None if len(a) == len(p.shape) else 1 / 0,
                 axes, params, is_leaf=is_axes)
