"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --smoke \
        --requests 6 --max-new 16

Serves synthetic prompts through the ServeEngine (prefill + lock-step decode)
with per-request energy attribution from the telemetry tag bus.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = build_model(cfg, q_block=min(64, args.prompt_len))
    params, _ = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, batch_size=args.batch,
                         max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    stats = engine.serve(reqs)
    print(f"arch={cfg.name} reqs={args.requests} "
          f"prefill={stats['prefill_s']*1e3:.0f}ms "
          f"decode={stats['decode_s']*1e3:.0f}ms "
          f"({stats['decode_tok_per_s']:.1f} tok/s)")
    if "energy_by_tag" in stats:
        print("energy by tag (J):",
              {k: round(v, 2) for k, v in stats["energy_by_tag"].items()})
    for r in reqs:
        print(f"  req {r.req_id}: {len(r.output)} tokens")
    return stats


if __name__ == "__main__":
    main()
