"""Continuous-batching engine: mid-decode joins, slot recycling (EOS and
length), equivalence with the static engine, per-request energy attribution,
and the energy-aware admission policy (power capping, shedding)."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve.engine import ContinuousEngine, Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke("granite-20b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


def _mk_reqs(cfg, n, plen=8, max_new=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=max_new, **kw) for i in range(n)]


def test_matches_static_engine(setup):
    """Per-slot positions + slot prefill reproduce the static engine's
    tokens exactly (equal-length prompts, greedy)."""
    cfg, model, params = setup
    a = _mk_reqs(cfg, 3, seed=3)
    b = _mk_reqs(cfg, 3, seed=3)
    ServeEngine(model, params, batch_size=4, max_seq=48,
                telemetry=False).serve(a)
    ContinuousEngine(model, params, batch_size=4, max_seq=48,
                     telemetry=False).serve(b)
    for ra, rb in zip(a, b):
        assert ra.output == rb.output


def test_requests_join_mid_decode(setup):
    """More requests than slots: late requests join as early ones finish;
    every slot is recycled and all requests complete at their budgets."""
    cfg, model, params = setup
    reqs = [Request(i, np.random.default_rng(i).integers(
                        0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3 + (i % 3) * 4) for i in range(7)]
    eng = ContinuousEngine(model, params, batch_size=3, max_seq=48)
    stats = eng.serve(reqs)
    assert stats["completed"] == 7
    assert stats["prefills"] == 7
    assert stats["slots_recycled"] == 7
    assert stats["peak_active"] == 3          # slots were actually shared
    for r in reqs:
        assert len(r.output) == r.max_new_tokens
        assert r.finish_reason == "length"
    # recycling means strictly fewer decode steps than the serialized sum
    assert stats["decode_steps"] < sum(r.max_new_tokens for r in reqs)


def test_slot_recycling_after_eos(setup):
    """A request hitting EOS frees its slot immediately for the next
    queued request."""
    cfg, model, params = setup
    probe = _mk_reqs(cfg, 1, seed=5, max_new=8)
    ContinuousEngine(model, params, batch_size=2, max_seq=48,
                     telemetry=False).serve(probe)
    out = probe[0].output                    # greedy => deterministic rerun
    k = next((i for i in range(1, len(out)) if out[i] not in out[:i]), None)
    if k is None:
        pytest.skip("model repeats one token; no usable EOS position")
    eos = out[k]
    rng = np.random.default_rng(5)
    reqs = [Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=8, eos_id=eos)]
    reqs += _mk_reqs(cfg, 2, seed=6, max_new=4)
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=48,
                           telemetry=False)
    stats = eng.serve(reqs)
    assert reqs[0].finish_reason == "eos"
    assert len(reqs[0].output) == k + 1      # stopped at the EOS token
    assert stats["completed"] == 3
    assert stats["slots_recycled"] == 3
    assert all(len(r.output) == 4 for r in reqs[1:])


def test_per_request_energy_sums_to_board_total(setup):
    """Tag-bus attribution: request shares partition the board energy."""
    cfg, model, params = setup
    reqs = _mk_reqs(cfg, 5, seed=7, max_new=5)
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=48)
    stats = eng.serve(reqs)
    total = stats["energy_j"]
    parts = sum(r.energy_j for r in reqs)
    assert total > 0.0
    assert all(r.energy_j > 0.0 for r in reqs)
    assert abs(total - parts) <= 1e-6 + 0.01 * total
    # J/token is per-request derivable
    for r in reqs:
        assert r.energy_j / len(r.output) > 0.0


def test_power_cap_limits_concurrency(setup):
    """A cap between the modeled 2- and 3-slot average power defers
    admissions so at most two slots run concurrently."""
    cfg, model, params = setup
    pm = ContinuousEngine(model, params, batch_size=4, max_seq=48,
                          telemetry=False).pm     # engine's own power model
    cap = (pm.avg_power_w(2) + pm.avg_power_w(3)) / 2
    eng = ContinuousEngine(model, params, batch_size=4, max_seq=48,
                           power_cap_w=cap, telemetry=False)
    assert eng.admission.max_slots(4) == 2
    reqs = _mk_reqs(cfg, 5, seed=8, max_new=4)
    stats = eng.serve(reqs)
    assert stats["completed"] == 5
    assert stats["peak_active"] <= 2
    assert stats["shed"] == 0


def test_unreachable_power_cap_sheds(setup):
    """A cap below even single-slot power sheds the whole queue."""
    cfg, model, params = setup
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=48,
                           power_cap_w=1.0, telemetry=False)
    reqs = _mk_reqs(cfg, 3, seed=9, max_new=4)
    stats = eng.serve(reqs)
    assert stats["shed"] == 3 and stats["completed"] == 0
    assert all(r.finish_reason == "shed-cap" for r in reqs)
    assert all(r.output == [] for r in reqs)


def test_ttl_shed_uses_measured_throughput(setup):
    """Requests whose predicted wait (from the measured decode rate)
    exceeds their TTL are shed instead of queued forever."""
    cfg, model, params = setup
    head = _mk_reqs(cfg, 1, seed=10, max_new=10)
    stale = _mk_reqs(cfg, 2, seed=11, max_new=10, ttl_s=1e-6)
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=48,
                           telemetry=False)
    stats = eng.serve(head + stale)
    assert head[0].finish_reason == "length"
    assert stats["shed"] == 2
    assert all(r.finish_reason == "shed" for r in stale)


def test_zero_budget_request_is_accounted(setup):
    """max_new_tokens=0 requests finish (reason: length) and still count."""
    cfg, model, params = setup
    reqs = [Request(0, np.arange(4, dtype=np.int32), max_new_tokens=0)]
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=48,
                           telemetry=False)
    stats = eng.serve(reqs)
    assert stats["completed"] == 1 and stats["shed"] == 0
    assert reqs[0].finish_reason == "length" and reqs[0].output == []


def test_windowed_model_continuous(setup):
    """gemma3-style local:global ring caches work with per-slot positions."""
    cfg = configs.get_smoke("gemma3-27b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(1))
    a = _mk_reqs(cfg, 2, seed=12, max_new=4)
    b = _mk_reqs(cfg, 2, seed=12, max_new=4)
    ServeEngine(model, params, batch_size=2, max_seq=32,
                telemetry=False).serve(a)
    ContinuousEngine(model, params, batch_size=2, max_seq=32,
                     telemetry=False).serve(b)
    for ra, rb in zip(a, b):
        assert ra.output == rb.output
