"""Per-cell profile: top collective and memory contributors with loop
multipliers — the 'profiler' for the hypothesis -> change -> measure loop
(§Perf). Works from the compiled HLO text of a dry-run cell.

    PYTHONPATH=src python -m repro.perf.diagnose --arch granite-20b \
        --shape train_4k --mesh single
"""
from __future__ import annotations

import argparse
from collections import defaultdict

from repro.perf import hlo_cost


def walk_with_multipliers(mc: hlo_cost.ModuleCost):
    """Yield (comp_name, multiplier) reachable from entry (while-aware)."""
    out = defaultdict(float)

    def walk(name, m):
        out[name] += m
        comp = mc.comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                t = hlo_cost._TRIP.search(ins.rest)
                trips = int(t.group(1)) if t else 1
                for rx in (hlo_cost._WHILE_BODY, hlo_cost._WHILE_COND):
                    mm = rx.search(ins.rest)
                    if mm:
                        walk(mm.group(1), m * trips)

    walk(mc.entry, 1.0)
    return out


def report(text: str, pod_block=None, top=15):
    mc = hlo_cost.ModuleCost(text, pod_block)
    mult = walk_with_multipliers(mc)

    coll_rows, mem_rows, flop_rows = [], [], []
    for name, m in mult.items():
        comp = mc.comps[name]
        for ins in comp.instrs:
            base = ins.op.replace("-start", "")
            if base in hlo_cost.COLLECTIVE_OPS and not ins.op.endswith("-done"):
                b, g, crosses = hlo_cost._parse_collective(ins, mc.pod_block)
                coll_rows.append((b * m, base, g, crosses, m,
                                  ins.type_str[:48]))
            if base in hlo_cost._SKIP_BYTES_OPS or base == "while":
                continue
            mem_rows.append((mc._instr_bytes(comp, ins) * m, ins.op,
                             m, ins.type_str[:48], ins.name[:40]))
            if base in ("dot", "dot-general", "fusion", "call"):
                sub = hlo_cost._CALLS.search(ins.rest)
                fl = 0.0
                if base in ("dot", "dot-general"):
                    tot = hlo_cost.CostTotals()
                    # reuse comp_cost pieces: quick local dot flops
                    res = 1
                    for d in hlo_cost._shape_dims(ins.type_str):
                        res *= d
                    lhs_c = hlo_cost._LHS_C.search(ins.rest)
                    contract = 1
                    names = hlo_cost._OPERAND.findall(
                        ins.rest.split(")", 1)[0])
                    if lhs_c and names:
                        dims = hlo_cost._shape_dims(
                            mc._resolve_type(comp, names[0]))
                        for idx in lhs_c.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                contract *= dims[int(idx)]
                    fl = 2.0 * res * contract
                elif sub:
                    fl = mc.comp_cost(sub.group(1)).flops
                if fl:
                    flop_rows.append((fl * m, ins.op, m, ins.type_str[:40],
                                      ins.name[:40]))

    lines = []
    totals = mc.totals()
    coll_total = sum(r[0] for r in coll_rows)
    lines.append(f"== totals: flops={totals.flops:.3e} bytes={totals.bytes:.3e} "
                 f"collective_bytes={coll_total:.3e}")
    lines.append("-- top collectives (bytes x count):")
    for b, op, g, crosses, m, t in sorted(coll_rows, reverse=True)[:top]:
        lines.append(f"  {b:10.3e}  {op:<18} g={g:<4} x{m:<6.0f} "
                     f"{'DCN' if crosses else 'ici'}  {t}")
    lines.append("-- top memory instructions:")
    for b, op, m, t, nm in sorted(mem_rows, reverse=True)[:top]:
        lines.append(f"  {b:10.3e}  {op:<18} x{m:<6.0f} {t}  {nm}")
    lines.append("-- top flops instructions:")
    for f, op, m, t, nm in sorted(flop_rows, reverse=True)[:top]:
        lines.append(f"  {f:10.3e}  {op:<18} x{m:<6.0f} {t}  {nm}")
    return "\n".join(lines)


def main():
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    jitted, cargs, cfg, shape, info = build_cell(args.arch, args.shape, mesh)
    with mesh:
        compiled = jitted.lower(*cargs).compile()
    print(compiled.memory_analysis())
    text = compiled.as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(text)
    print(report(text, 256 if args.mesh == "multi" else None, args.top))


if __name__ == "__main__":
    main()
