"""DLK009 interproc-host-sync + DLK011 ownership-handoff.

Both rules ride on :class:`repro.analysis.project.ProjectIndex` function
summaries (``ctx.project``), so taint and ownership cross function and
module boundaries — the exact escape hatch of the module-local DLK002 /
DLK006 / DLK007: the moment a jitted result or a pool/tracer handle is
passed to a helper, the local rules lose it.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Finding, ModuleContext, Rule, register
from repro.analysis.rules_host import _sync_call
from repro.analysis.rules_obs import _tracer_receiver
from repro.analysis.rules_refcount import _pool_receiver


def _in_loop(ctx: ModuleContext, node, fn) -> bool:
    """Is ``node`` inside a loop that belongs to ``fn`` (not an outer one)?"""
    for anc in ctx.ancestors(node):
        if anc is fn:
            return False
        if isinstance(anc, (ast.For, ast.AsyncFor, ast.While)):
            return True
    return False


@register
class InterprocHostSync(Rule):
    """Device value synced to host inside a helper called from a hot loop.
    DLK002 stops at the function boundary; this rule follows the call graph:
    the helper's summary says which of its parameters it syncs, and the
    caller's taint says which arguments hold device values."""

    code = "DLK009"
    name = "interproc-host-sync"
    skip_tests = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        proj = ctx.project
        if proj is None:
            return
        for fn in ctx.functions:
            if not any(isinstance(n, (ast.For, ast.AsyncFor, ast.While))
                       for n in ast.walk(fn)):
                continue
            device = proj.device_names(ctx, fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if not _in_loop(ctx, node, fn):
                    continue
                if _sync_call(node, ctx) is not None:
                    continue                    # direct sync: DLK002's beat
                target = proj.resolve_call(ctx, node)
                if target is None:
                    continue
                info, bound = target
                summ = proj.summaries.get(info.fq)
                if summ is None or not summ.syncs_params:
                    continue
                for pi, arg in proj.map_args(node, info, bound).items():
                    if pi not in summ.syncs_params:
                        continue
                    tainted = any(
                        (isinstance(sub, ast.Name) and sub.id in device)
                        or (isinstance(sub, ast.Call)
                            and proj.is_device_call(ctx, sub))
                        for sub in ast.walk(arg))
                    if not tainted:
                        continue
                    param = summ.params[pi] if pi < len(summ.params) \
                        else f"#{pi}"
                    site = summ.sync_sites.get(pi, "host sync")
                    yield ctx.finding(
                        self, node,
                        f"device value flows into {info.fq}() which syncs "
                        f"its '{param}' argument to host ({site}) — called "
                        f"every iteration of a loop in '{fn.name}', this "
                        "stalls the dispatch queue just like an inline sync")
                    break


def _handle_call(call: ast.Call, ctx: ModuleContext):
    """(kind, receiver) if this call mints an owned handle: a pool block
    (``<pool>.alloc()``) or a tracer span (``<tracer>.begin/span()``)."""
    if not isinstance(call.func, ast.Attribute):
        return None
    attr = call.func.attr
    if attr == "alloc":
        recv = _pool_receiver(call.func)
        if recv is not None:
            return "block", recv
    if attr in ("begin", "span"):
        recv = _tracer_receiver(call.func)
        if recv is not None:
            return "span", recv
    return None


@register
class OwnershipHandoff(Rule):
    """Block/span handle passed to a function that does not consume it.
    DLK006/DLK007 treat any call argument as an ownership transfer; with a
    resolved callee summary we know whether the callee actually stores,
    returns, frees, or ends the handle — if it does not, and no other use
    settles ownership here, the handle leaks across the call boundary."""

    code = "DLK011"
    name = "ownership-handoff"
    skip_tests = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        proj = ctx.project
        if proj is None:
            return
        from repro.analysis.project import CONSUME_METHODS
        for fn in ctx.functions:
            handles = {}
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and isinstance(node.value, ast.Call):
                    minted = _handle_call(node.value, ctx)
                    if minted is not None:
                        handles[node.targets[0].id] = (node, minted)
            for name in sorted(handles):
                bind, (kind, recv) = handles[name]
                uses = [n for n in ast.walk(fn)
                        if isinstance(n, ast.Name) and n.id == name
                        and isinstance(n.ctx, ast.Load)]
                if not uses:
                    continue        # dropped handle: DLK006/DLK007 territory
                consumed = False
                handoffs = []
                for use in uses:
                    verdict = self._classify(ctx, proj, fn, use,
                                             CONSUME_METHODS)
                    if verdict == "consumed":
                        consumed = True
                        break
                    if verdict is not None:
                        handoffs.append(verdict)
                if consumed or not handoffs:
                    continue
                call, info = handoffs[0]
                yield ctx.finding(
                    self, call,
                    f"{kind} handle '{name}' from {recv}."
                    f"{bind.value.func.attr}() is passed to {info.fq}(), "
                    "which neither stores, returns, frees, nor ends it — "
                    "and no other use here settles ownership (leak)")

    @staticmethod
    def _classify(ctx, proj, fn, use, consume_methods):
        """'consumed', (call, info) for a non-consuming handoff, or None
        for a neutral use (guard test, attribute read)."""
        parent = ctx.parent(use)
        for anc in ctx.ancestors(use):
            if anc is fn:
                break
            if isinstance(anc, (ast.Return, ast.Yield, ast.YieldFrom)):
                return "consumed"
            if isinstance(anc, (ast.With, ast.AsyncWith)):
                if any(use is sub or any(s is use for s in
                                         ast.walk(item.context_expr))
                       for item in anc.items
                       for sub in [item.context_expr]):
                    return "consumed"
            if isinstance(anc, ast.Assign) and any(
                    isinstance(t, (ast.Attribute, ast.Subscript))
                    for t in anc.targets):
                return "consumed"
        # h.end()/h.free()/h.close()/h.release()
        if isinstance(parent, ast.Attribute) \
                and parent.attr in consume_methods:
            gp = ctx.parent(parent)
            if isinstance(gp, ast.Call) and gp.func is parent:
                return "consumed"
        # argument to a call
        if isinstance(parent, ast.Call) and use is not parent.func:
            in_args = any(a is use for a in parent.args) or any(
                kw.value is use for kw in parent.keywords)
            if in_args:
                target = proj.resolve_call(ctx, parent)
                if target is None:
                    return "consumed"   # unresolvable: assume transfer
                info, bound = target
                summ = proj.summaries.get(info.fq)
                if summ is None:
                    return "consumed"
                for pi, arg in proj.map_args(parent, info, bound).items():
                    if arg is use:
                        if pi in summ.consumes_params:
                            return "consumed"
                        return (parent, info)
                return "consumed"       # star-args etc.: assume transfer
        return None
