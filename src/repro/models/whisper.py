"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, enc_seq, D]. Positions are
sinusoidal for both encoder and decoder (the learned decoder table is
replaced so that arbitrary assigned decode lengths lower without a
config-coupled table size; noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import ParamBuilder
from repro.parallel.sharding import Sharder


def sinusoid(positions, d_model, dtype):
    """Sinusoidal embeddings for positions of any shape: [...,] -> [..., D]."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _cross_attn_init(pb: ParamBuilder, cfg: ModelConfig, L):
    d, h, dh = cfg.d_model, cfg.q_heads, cfg.head_dim
    pre, pax = (L,), ("layers",)
    pb.dense("wq", pre + (d, h, dh), pax + ("embed", "heads", "head_dim"), fan_in=d)
    pb.dense("wk", pre + (d, h, dh), pax + ("embed", "heads", "head_dim"), fan_in=d)
    pb.dense("wv", pre + (d, h, dh), pax + ("embed", "heads", "head_dim"), fan_in=d)
    pb.dense("wo", pre + (h, dh, d), pax + ("heads", "head_dim", "embed"), fan_in=h * dh)


def cross_kv(enc_out, p):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def cross_attention(x, p, k, v, cfg, shd: Sharder):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q = shd(q, "batch", "seq", "act_heads", None)
    dh = q.shape[-1]
    s = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(dh)
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhst,bthd->bshd", pr, v)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return shd(out, "batch", "seq", "act_embed")


class Whisper:
    def __init__(self, cfg: ModelConfig, mesh=None, *, attn_impl="blocked",
                 q_block=512, remat=True, shd_rules=None, barrier=False):
        self.cfg = cfg
        self.shd = Sharder(mesh, rules=shd_rules, barrier=barrier)
        self.attn_impl = attn_impl
        self.q_block = q_block
        self.remat = remat

    def init(self, key):
        cfg = self.cfg
        pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
        common.embed_init(pb, cfg)
        # encoder
        eb = pb.child("encoder")
        eb.dense("norm1", (cfg.enc_layers, cfg.d_model), ("layers", "norm"), zero=True)
        eb.dense("norm2", (cfg.enc_layers, cfg.d_model), ("layers", "norm"), zero=True)
        ab = eb.child("attn")
        common.attn_init(ab, cfg, cfg.enc_layers)
        mb = eb.child("mlp")
        common.mlp_init(mb, cfg.d_model, cfg.d_ff, cfg.enc_layers)
        pb.dense("enc_final_norm", (cfg.d_model,), ("norm",), zero=True)
        # decoder
        db = pb.child("decoder")
        db.dense("norm1", (cfg.num_layers, cfg.d_model), ("layers", "norm"), zero=True)
        db.dense("norm_x", (cfg.num_layers, cfg.d_model), ("layers", "norm"), zero=True)
        db.dense("norm2", (cfg.num_layers, cfg.d_model), ("layers", "norm"), zero=True)
        sb = db.child("self_attn")
        common.attn_init(sb, cfg, cfg.num_layers)
        xb = db.child("cross_attn")
        _cross_attn_init(xb, cfg, cfg.num_layers)
        fb = db.child("mlp")
        common.mlp_init(fb, cfg.d_model, cfg.d_ff, cfg.num_layers)
        return pb.build()

    # -- encoder -------------------------------------------------------------

    def encode(self, params, frames):
        """frames: [B, enc_seq, D] precomputed embeddings (stub frontend)."""
        cfg, shd = self.cfg, self.shd
        dtype = jnp.dtype(cfg.dtype)
        x = frames.astype(dtype)
        positions = jnp.arange(x.shape[1])
        x = x + sinusoid(positions, cfg.d_model, dtype)[None]
        x = shd(x, "batch", "seq", "act_embed")

        def body(carry, p):
            xc = carry
            h, _ = common.attention(
                common.rms_norm(xc, p["norm1"]), p["attn"], cfg, shd,
                positions=positions, causal=False, impl=self.attn_impl,
                q_block=self.q_block, use_rope=False)
            xc = xc + h
            xc = xc + common.mlp(common.rms_norm(xc, p["norm2"]), p["mlp"], shd)
            return xc, None

        if self.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = lax.scan(body, x, params["encoder"])
        return common.rms_norm(x, params["enc_final_norm"])

    # -- decoder -------------------------------------------------------------

    def _decoder_stack(self, x, params, enc_out, *, positions, caches=None,
                       cache_pos=None, cross_cache=None):
        cfg, shd = self.cfg, self.shd
        del enc_out  # decoder consumes the precomputed cross_cache
        dp = params["decoder"]

        def body(carry, inp):
            xc = carry
            if caches is None:
                p, xk, xv = inp
                c, cpos = None, None
            else:
                p, xk, xv, sk, sv = inp
                c, cpos = (sk, sv), cache_pos
            h, nc = common.attention(
                common.rms_norm(xc, p["norm1"]), p["self_attn"], cfg, shd,
                positions=positions, impl=self.attn_impl,
                q_block=self.q_block, use_rope=False, kv_cache=c,
                cache_pos=cpos)
            xc = xc + h
            xc = xc + cross_attention(
                common.rms_norm(xc, p["norm_x"]), p["cross_attn"], xk, xv,
                cfg, shd)
            xc = xc + common.mlp(common.rms_norm(xc, p["norm2"]), p["mlp"], shd)
            y = None if nc is None else nc
            return xc, y

        if self.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        xk_all, xv_all = cross_cache
        if caches is None:
            x, _ = lax.scan(body, x, (dp, xk_all, xv_all))
            return x, None
        x, ys = lax.scan(body, x, (dp, xk_all, xv_all, caches[0], caches[1]))
        return x, ys

    def _embed_dec(self, params, tokens, positions):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = common.embed(tokens, params, dtype)
        pe = sinusoid(positions, cfg.d_model, dtype)
        # positions: [S] (shared across the batch) or [B, S] (per-row decode)
        x = x + (pe[None] if positions.ndim == 1 else pe)
        return self.shd(x, "batch", "seq", "act_embed")

    def build_cross_cache(self, params, enc_out):
        """Precompute per-layer cross K/V: [L, B, enc_seq, H, Dh]."""
        return jax.vmap(lambda p: cross_kv(enc_out, p))(
            params["decoder"]["cross_attn"])

    def forward(self, params, batch):
        """batch: {frames: [B,enc_seq,D], tokens: [B,S]}."""
        enc_out = self.encode(params, batch["frames"])
        cross_cache = self.build_cross_cache(params, enc_out)
        positions = jnp.arange(batch["tokens"].shape[1])
        x = self._embed_dec(params, batch["tokens"], positions)
        x, _ = self._decoder_stack(x, params, enc_out, positions=positions,
                                   cross_cache=cross_cache)
        return common.unembed(x, params, self.shd), 0.0

    def init_cache(self, batch_size, max_seq, dtype=jnp.bfloat16):
        cfg = self.cfg
        self_shape = (cfg.num_layers, batch_size, max_seq, cfg.num_kv_heads,
                      cfg.head_dim)
        cross_shape = (cfg.num_layers, batch_size, cfg.enc_seq, cfg.q_heads,
                       cfg.head_dim)
        return {
            "self": (jnp.zeros(self_shape, dtype), jnp.zeros(self_shape, dtype)),
            "cross": (jnp.zeros(cross_shape, dtype), jnp.zeros(cross_shape, dtype)),
        }

    def cache_axes(self):
        ax_self = ("layers", "batch", "kv_seq", "act_kv_heads", None)
        ax_cross = ("layers", "batch", None, "act_heads", None)
        return {"self": (ax_self, ax_self), "cross": (ax_cross, ax_cross)}

    def prefill(self, params, batch, caches, start_pos=None):
        """Prefill decoder tokens at absolute positions [start, start+S).

        ``batch["frames"]`` is required on the first chunk (encodes audio
        and fills ``caches["cross"]``); later chunks omit it and reuse the
        carried cross cache, so a long transcript prompt can be fed in pow2
        chunks without re-encoding."""
        caches = dict(caches)
        if batch.get("frames") is not None:
            enc_out = self.encode(params, batch["frames"])
            xk, xv = self.build_cross_cache(params, enc_out)
            caches["cross"] = (xk.astype(caches["cross"][0].dtype),
                               xv.astype(caches["cross"][1].dtype))
        cc = (caches["cross"][0].astype(jnp.dtype(self.cfg.dtype)),
              caches["cross"][1].astype(jnp.dtype(self.cfg.dtype)))
        offset = jnp.int32(0) if start_pos is None else start_pos
        positions = jnp.arange(batch["tokens"].shape[1]) + offset
        x = self._embed_dec(params, batch["tokens"], positions)
        x, ys = self._decoder_stack(x, params, None, positions=positions,
                                    caches=caches["self"], cache_pos=offset,
                                    cross_cache=cc)
        caches["self"] = ys
        return common.unembed(x[:, -1:], params, self.shd), caches

    def decode_step(self, params, token, pos, caches):
        """One decode step. pos: scalar int32 or [B] int32 (continuous
        batching: each row decodes at its own position)."""
        cfg = self.cfg
        if jnp.ndim(pos) == 0:
            positions = jnp.array([0], jnp.int32) + pos
        else:
            positions = pos.astype(jnp.int32)[:, None]   # [B, 1]
        x = self._embed_dec(params, token, positions)
        cc = (caches["cross"][0].astype(jnp.dtype(cfg.dtype)),
              caches["cross"][1].astype(jnp.dtype(cfg.dtype)))
        x, ys = self._decoder_stack(x, params, None, positions=positions,
                                    caches=caches["self"], cache_pos=pos,
                                    cross_cache=cc)
        caches = dict(caches, self=ys)
        return common.unembed(x, params, self.shd), caches
