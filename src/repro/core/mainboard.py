"""Main-board aggregator (paper Sec. 4.1).

One PIC18-based board per node: two I2C connectors, up to six probes
daisy-chained per connector (12 max), 5 V USB power + data. The I2C bus is
the bottleneck: the bus budget is ``PROBES_PER_BUS * REPORT_SPS`` report
slots per second, so six probes sustain the full 1000 SPS each and an
oversubscribed chain (``attach(..., oversubscribe=True)`` past the paper's
recommended six) degrades every probe on that bus proportionally
(``effective_sps``). Eight GPIO inputs tag samples with code regions.

We model the board faithfully: bus budget enforcement, per-probe report
streams at their degraded rates, tag annotation at sample timestamps, and a
host-side API mirroring the planned C API (paper Sec. 4.3):

``read_samples``  legacy per-object ``Sample`` lists;
``read_block``    columnar ``repro.telemetry.samples.SampleBlock`` per probe
                  (the default path under ``repro.telemetry``).

Energy integration uses each stream's actual report period — not a
hardcoded ``1/REPORT_SPS`` — so oversubscribed streams integrate correctly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.probe import REPORT_SPS, Probe, Sample
from repro.core.tags import TagBus

N_I2C_BUSES = 2
PROBES_PER_BUS = 6
MAX_PROBES = N_I2C_BUSES * PROBES_PER_BUS
BUS_MAX_SPS = PROBES_PER_BUS * REPORT_SPS   # paper: 1000 SPS with 6 probes


class MainBoard:
    """Aggregates probes over two I2C buses; attaches GPIO tags to samples."""

    def __init__(self, node_name: str = "node", clock_t0: float = 0.0):
        self.node_name = node_name
        self._buses: List[List[Probe]] = [[], []]
        self._tags = TagBus(clock=self._now)
        self._t = clock_t0

    # -- virtual clock (simulation time) ------------------------------------

    def _now(self) -> float:
        return self._t

    def advance(self, dt: float):
        self._t += dt

    @property
    def now(self) -> float:
        return self._t

    @property
    def tags(self) -> TagBus:
        return self._tags

    # -- probe management ----------------------------------------------------

    def attach(self, probe: Probe, bus: Optional[int] = None,
               oversubscribe: bool = False) -> int:
        """Attach a probe; ``oversubscribe=True`` allows daisy-chaining past
        the paper's six-per-connector recommendation, trading per-probe
        report rate (I2C budget is shared — see ``effective_sps``)."""
        if bus is None:
            bus = 0 if len(self._buses[0]) <= len(self._buses[1]) else 1
        if not 0 <= bus < N_I2C_BUSES:
            raise ValueError(f"bus {bus} out of range")
        if len(self._buses[bus]) >= PROBES_PER_BUS and not oversubscribe:
            raise RuntimeError(
                f"I2C bus {bus} full ({PROBES_PER_BUS} probes max — paper HW limit)")
        self._buses[bus].append(probe)
        return bus

    @property
    def n_probes(self) -> int:
        return sum(len(b) for b in self._buses)

    def effective_sps(self, bus: int) -> float:
        """Per-probe report rate on a bus (I2C budget shared)."""
        n = len(self._buses[bus])
        if n == 0:
            return 0.0
        return min(REPORT_SPS, BUS_MAX_SPS / n)

    def probes(self) -> List[tuple]:
        """(probe_id, bus, probe, effective_sps) rows in stream order."""
        out, pid = [], 0
        for b, bus in enumerate(self._buses):
            sps = self.effective_sps(b)
            for probe in bus:
                out.append((pid, b, probe, sps))
                pid += 1
        return out

    # -- sampling ------------------------------------------------------------

    def read_samples(self, duration: float) -> Dict[int, List[Sample]]:
        """Advance time by ``duration`` and return per-probe samples with
        the GPIO tags that were active at each sample timestamp. Each probe
        reports at its bus's ``effective_sps``."""
        t0 = self._t
        out: Dict[int, List[Sample]] = {}
        idx = self._tags.index()
        for pid, _, probe, sps in self.probes():
            samples = probe.read(t0, duration, sps=sps)
            out[pid] = [dataclasses.replace(s, tags=idx.active_at(s.t))
                        for s in samples]
        self._t = t0 + duration
        return out

    def read_block(self, duration: float) -> Dict[int, "SampleBlock"]:
        """Columnar read: per-probe ``SampleBlock`` (numpy columns + GPIO
        bitmask) — the fast path ``repro.telemetry`` routes through."""
        from repro.telemetry.samples import read_board_blocks
        return read_board_blocks(self, duration)

    # -- energy accounting ---------------------------------------------------

    @staticmethod
    def energy_j(samples: List[Sample], dt: Optional[float] = None) -> float:
        """Samples are averaged power over fixed report intervals: energy is
        each report's power times its actual integration period (``s.dt``,
        set by the read path from the stream's effective rate); pass ``dt``
        to override."""
        if dt is not None:
            return sum(s.watts for s in samples) * dt
        return sum(s.watts * s.dt for s in samples)

    @staticmethod
    def energy_by_tag(samples: List[Sample],
                      dt: Optional[float] = None) -> Dict[str, float]:
        """Per-tag energy attribution (paper Sec. 4.1: GPIO-synchronized
        fine-grained profiling)."""
        out: Dict[str, float] = {}
        for s in samples:
            for tag in s.tags:
                out[tag] = out.get(tag, 0.0) + s.watts * (dt if dt is not None
                                                          else s.dt)
        return out
