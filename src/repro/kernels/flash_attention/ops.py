"""Jit'd wrapper for the flash attention kernel."""
import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def attention(q, k, v, causal=True, window=None, block_q=128, block_kv=128,
              interpret=False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_kv=block_kv,
                           interpret=interpret)
