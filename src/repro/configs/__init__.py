from repro.configs.base import (
    ARCH_NAMES, SHAPES, ModelConfig, ShapeConfig, all_cells, get, get_smoke,
    shape_cells,
)
