"""Paper Fig. 9 (Sec. 5.6): SSD throughput -> checkpoint I/O.

Sequential write/read of a sharded checkpoint (the cluster's real SSD
workload) + many-small-leaves variant (random-access pattern).
"""
import shutil
import tempfile

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.checkpoint import ckpt


def run():
    big = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(64, 1024, 256)), jnp.float32)}            # 64 MB
    small = {f"l{i}": jnp.zeros((1024,), jnp.float32) for i in range(256)}
    nbytes = 64 * 1024 * 256 * 4
    for name, tree, size in (("seq", big, nbytes),
                             ("small_leaves", small, 256 * 4096)):
        d = tempfile.mkdtemp()
        try:
            t_w = time_fn(lambda: ckpt.save(tree, d, 1), warmup=1, iters=3)
            t_r = time_fn(lambda: ckpt.restore(tree, d), warmup=1, iters=3)
            emit(f"ckpt/{name}/write", t_w, f"{size / t_w / 1e6:.0f}MB/s")
            emit(f"ckpt/{name}/read", t_r, f"{size / t_r / 1e6:.0f}MB/s")
        finally:
            shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    run()
