"""Paged KV cache + radix prefix-cache sharing.

The correctness anchor is bit-exactness: serving through block-table
indirection (gather -> unmodified model step -> scatter) must equal the
contiguous per-slot cache bit-for-bit — logits, sampled tokens, AND cache
contents — across block sizes, prompt lengths, bucket edges, and shuffled
physical block layouts. On top of the allocator: a prefix-cache *hit*
(matched blocks mapped with zero prefill compute) must produce exactly the
tokens a cold prefill produces; sharing must be isolation-safe (refcounts +
copy-on-write); and the compile budget must stay at the bucketed-prefill
baseline — table values are traced, so remaps never retrace. Satellites
ride along: the ``advance`` clamp fix (finish_reason "capacity"), page-aware
admission deferral, LRU trie eviction under pool pressure, cache-aware
queue pricing, and hit-rate/cached-token accounting in run stats and
telemetry events."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import configs
from repro.models import build_model
from repro.models.common import paged_gather
from repro.models.registry import serving_caps
from repro.serve.engine import ContinuousEngine, Request
from repro.serve.paging import (PagePool, RadixPrefixCache,
                                resolve_kv_block_size)
from repro.serve.queue import RequestQueue
from repro.serve.step import (make_decode_step, make_paged_decode_step,
                              make_paged_slot_prefill, make_slot_prefill)

MAX_SEQ = 32


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_smoke("granite-20b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def paged_steps(dense):
    _, model, _ = dense
    return (jax.jit(make_slot_prefill(model)),
            jax.jit(make_paged_slot_prefill(model)),
            jax.jit(make_decode_step(model)),
            jax.jit(make_paged_decode_step(model)))


def _mk_reqs(cfg, n, plen=8, max_new=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=max_new, **kw) for i in range(n)]


# ---------------------------------------------------------------------------
# block-size resolution


def test_resolve_block_size():
    assert resolve_kv_block_size("auto", 64) == 32
    assert resolve_kv_block_size("auto", 48) == 16
    assert resolve_kv_block_size("auto", 24) == 8
    assert resolve_kv_block_size("auto", 7) is None    # nothing divides
    assert resolve_kv_block_size(None, 64) is None
    assert resolve_kv_block_size("off", 64) is None
    assert resolve_kv_block_size(16, 48) == 16
    with pytest.raises(ValueError):
        resolve_kv_block_size(32, 48)       # must divide max_seq
    # unsupported family: auto degrades silently, explicit raises
    assert resolve_kv_block_size("auto", 64, supported=False) is None
    with pytest.raises(ValueError):
        resolve_kv_block_size(16, 64, supported=False)


# ---------------------------------------------------------------------------
# allocator: refcounts, null block, COW, zero-on-free


def test_pool_alloc_free_refcount():
    pool = PagePool(n_slots=2, n_slot_blocks=2, n_blocks=5, block_size=8)
    assert pool.free_blocks() == 4           # block 0 reserved
    a, b = pool.alloc(), pool.alloc()
    assert a != PagePool.NULL and b != PagePool.NULL and a != b
    pool.retain(a)
    pool.free(a)
    assert pool.free_blocks() == 2           # still referenced once
    pool.free(a)
    assert pool.free_blocks() == 3
    assert a in pool.pending_zero            # must be scrubbed before reuse
    pool.free(b)
    assert sorted(pool.drain_pending_zero()) == sorted([a, b])
    assert pool.pending_zero == []


def test_pool_exhaustion_and_stats():
    pool = PagePool(n_slots=1, n_slot_blocks=3, n_blocks=4, block_size=4)
    got = [pool.alloc() for _ in range(3)]
    assert all(g is not None for g in got)
    assert pool.alloc() is None              # dry, not an exception
    assert pool.stats.peak_used == 3 and pool.stats.allocs == 3
    with pytest.raises(ValueError):
        PagePool(1, 4, 4, 4)                 # can't back one slot + null


def test_pool_shared_mapping_and_cow():
    pool = PagePool(n_slots=2, n_slot_blocks=2, n_blocks=6, block_size=8)
    blk = pool.alloc()
    pool.tables[0, 0] = blk
    pool.map_shared(1, [blk])                # slot 1 shares it
    assert pool.refcount[blk] == 2
    state, b, _ = pool.ensure_writable(0, pos=3)
    assert state == "cow" and b == blk       # shared: writer must copy
    dst = int(pool.tables[0, 0])
    assert dst != blk and pool.refcount[dst] == 1
    assert pool.refcount[blk] == 1           # writer's ref moved to the copy
    assert pool.stats.cow_copies == 1
    # exclusively owned now: plain ok
    assert pool.ensure_writable(0, pos=3)[0] == "ok"
    # unbacked boundary: fresh block
    state, nb, _ = pool.ensure_writable(0, pos=8)
    assert state == "new" and int(pool.tables[0, 1]) == nb
    pool.release_slot(0)
    assert pool.slot_blocks(0) == []
    assert pool.refcount[blk] == 1           # slot 1's ref survives


# ---------------------------------------------------------------------------
# radix trie: match/insert/probe/LRU eviction


def _trie(bs=4, n_blocks=12):
    pool = PagePool(n_slots=1, n_slot_blocks=4, n_blocks=n_blocks,
                    block_size=bs)
    return RadixPrefixCache(bs, pool), pool


def test_trie_match_caps_at_tail():
    trie, pool = _trie(bs=4)
    toks = np.arange(12, dtype=np.int32)
    blocks = [pool.alloc() for _ in range(3)]
    trie.insert(toks, blocks)
    assert len(trie) == 3
    # full 12-token prompt: at least one token must be left for prefill
    assert trie.match(toks) == blocks[:2]
    assert trie.match(np.arange(13, dtype=np.int32)) == blocks[:3]
    assert trie.match(np.arange(4, dtype=np.int32)) == []       # < 1 block + 1
    # diverging token breaks the chain at block granularity
    other = toks.copy()
    other[5] = 99
    assert trie.match(other) == blocks[:1]


def test_trie_probe_has_no_side_effects():
    trie, pool = _trie(bs=4)
    toks = np.arange(9, dtype=np.int32)
    trie.insert(toks, [pool.alloc(), pool.alloc()])
    before = (trie.stats.hits, trie.stats.misses)
    assert trie.probe(toks) == 8
    assert trie.probe(np.arange(100, 105, dtype=np.int32)) == 0
    assert (trie.stats.hits, trie.stats.misses) == before


def test_trie_refcounts_and_eviction():
    trie, pool = _trie(bs=4)
    a = np.arange(8, dtype=np.int32)
    b = np.concatenate([a[:4], np.arange(50, 54, dtype=np.int32)])
    ba = [pool.alloc(), pool.alloc()]
    bb = [ba[0], pool.alloc()]               # b shares a's first block
    trie.insert(a, ba)
    trie.insert(b, bb)     # shared head already cached: first writer wins,
    assert pool.refcount[ba[0]] == 2         # no second trie reference
    assert pool.refcount[bb[1]] == 2         # alloc's ref + the trie's
    # simulate the computing requests releasing their own refs
    for blk in set(ba + bb):
        pool.free(blk)
    assert trie.evictable_blocks() == 3      # trie is now the sole owner
    free0 = pool.free_blocks()
    assert trie.evict(1) == 1                # LRU leaf goes first
    assert pool.free_blocks() == free0 + 1
    assert trie.evict(10) == 2               # rest drains leaves-first
    assert len(trie) == 0
    assert trie.stats.evictions == 3


def test_trie_shared_block_not_evictable():
    trie, pool = _trie(bs=4)
    toks = np.arange(8, dtype=np.int32)
    blocks = [pool.alloc(), pool.alloc()]
    trie.insert(toks, blocks)                # refcount 2 (alloc + trie)
    assert trie.evictable_blocks() == 0      # a slot still references them
    pool.free(blocks[1])
    # tail is sole-owned but its parent is pinned: chain integrity holds,
    # the *leaf* may go while the pinned ancestor stays
    assert trie.evictable_blocks() == 1
    assert trie.evict(10) == 1
    assert trie.match(np.arange(5, dtype=np.int32)) == blocks[:1]


def test_trie_clear_returns_references():
    trie, pool = _trie(bs=4)
    toks = np.arange(8, dtype=np.int32)
    blocks = [pool.alloc(), pool.alloc()]
    trie.insert(toks, blocks)
    for blk in blocks:
        pool.free(blk)                       # request-side refs gone
    trie.clear()
    assert pool.free_blocks() == pool.stats.total_blocks
    assert trie.match(np.arange(9, dtype=np.int32)) == []


# ---------------------------------------------------------------------------
# bit-exactness: paged == contiguous through shuffled block tables


def _check_paged_matches_contiguous(cfg, model, params, steps, block_size,
                                    plens, n_decode, seed=0,
                                    max_seq=MAX_SEQ):
    """Prefill ``plens`` prompts into slots of a contiguous cache and into a
    paged pool through *shuffled* block tables, then decode ``n_decode``
    lock-steps: logits, tokens, and full cache contents must be bit-equal at
    every step."""
    prefill_c, prefill_p, decode_c, decode_p = steps
    n_slot_blocks = max_seq // block_size
    n_slots = len(plens)
    pool_n = n_slots * n_slot_blocks + 1
    rng = np.random.default_rng(seed)
    # shuffled physical layout: logical adjacency != physical adjacency
    perm = rng.permutation(np.arange(1, pool_n))
    tables = perm.reshape(n_slots, n_slot_blocks).astype(np.int32)
    cont = model.init_cache(n_slots, max_seq)
    pool = model.init_cache(pool_n, block_size)
    last = np.zeros((n_slots, 1), np.int32)
    for i, n in enumerate(plens):
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        tc, lc, cont = prefill_c(params, jnp.asarray(prompt[None]),
                                 jnp.int32(i), cont)
        tp, lp, pool = prefill_p(params, jnp.asarray(prompt[None]),
                                 jnp.int32(0), jnp.asarray(tables[i]), pool)
        assert np.array_equal(np.asarray(lc), np.asarray(lp)), \
            f"bs={block_size} len={n}: paged prefill logits differ"
        assert int(np.asarray(tc)[0, 0]) == int(np.asarray(tp)[0, 0])
        last[i, 0] = int(np.asarray(tc)[0, 0])
    pos = np.asarray(plens, np.int32)
    jt = jnp.asarray(tables)
    for step in range(n_decode):
        tc, lc, cont = decode_c(params, jnp.asarray(last),
                                jnp.asarray(pos), cont)
        tp, lp, pool = decode_p(params, jnp.asarray(last),
                                jnp.asarray(pos), jt, pool)
        assert np.array_equal(np.asarray(lc), np.asarray(lp)), \
            f"bs={block_size} step={step}: paged decode logits differ"
        assert np.array_equal(np.asarray(tc), np.asarray(tp))
        last = np.asarray(tc)
        pos = pos + 1
    # the gathered logical view must equal the contiguous cache bit-for-bit
    view = paged_gather(pool, jt)
    for xa, xb in zip(jax.tree.leaves(cont), jax.tree.leaves(view)):
        assert np.array_equal(np.asarray(xa), np.asarray(xb)), \
            f"bs={block_size}: paged cache contents differ from contiguous"


@pytest.mark.parametrize("block_size", [4, 8, 16])
def test_paged_matches_contiguous(dense, paged_steps, block_size):
    cfg, model, params = dense
    _check_paged_matches_contiguous(cfg, model, params, paged_steps,
                                    block_size, plens=(5, 13), n_decode=6,
                                    seed=block_size)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(block_size=st.sampled_from([4, 8, 16]),
           plen=st.integers(1, MAX_SEQ - 7),
           seed=st.integers(0, 900))
    def test_paged_matches_contiguous_property(dense, paged_steps,
                                               block_size, plen, seed):
        """Property form: any (block size, prompt length, content seed) is
        bit-exact through the paged indirection, including decode across
        block boundaries."""
        cfg, model, params = dense
        _check_paged_matches_contiguous(cfg, model, params, paged_steps,
                                        block_size, plens=(plen,),
                                        n_decode=5, seed=seed)


def test_paged_matches_contiguous_seeded(dense, paged_steps):
    """Deterministic sweep covering block-boundary edges (runs even without
    hypothesis): lengths on, just under, and just over block edges."""
    cfg, model, params = dense
    for bs, plen in [(4, 3), (4, 4), (4, 5), (8, 7), (8, 8), (8, 9),
                     (16, 15), (16, 16), (16, 17), (8, 1), (8, 25)]:
        _check_paged_matches_contiguous(cfg, model, params, paged_steps, bs,
                                        plens=(plen,), n_decode=4,
                                        seed=bs * 100 + plen)


# ---------------------------------------------------------------------------
# engine equivalence: paged engine == contiguous engine, token for token


def test_engine_paged_matches_contiguous(dense):
    cfg, model, params = dense
    assert serving_caps(model.cfg).paged_kv
    a, b = _mk_reqs(cfg, 4, seed=11), _mk_reqs(cfg, 4, seed=11)
    ea = ContinuousEngine(model, params, batch_size=2, max_seq=48,
                          telemetry=False)                    # paged (auto)
    eb = ContinuousEngine(model, params, batch_size=2, max_seq=48,
                          telemetry=False, kv_block_size="off")
    sa, sb = ea.serve(a), eb.serve(b)
    assert ea.block_size == 16 and eb.block_size is None
    for ra, rb in zip(a, b):
        assert ra.output == rb.output
    assert sa["tokens_decoded"] == sb["tokens_decoded"]
    assert sa["kv_pages"]["cow_copies"] == 0    # full-block-only sharing


def test_engine_explicit_block_size_matches(dense):
    cfg, model, params = dense
    a, b = _mk_reqs(cfg, 3, plen=11, seed=5), _mk_reqs(cfg, 3, plen=11, seed=5)
    ContinuousEngine(model, params, batch_size=3, max_seq=MAX_SEQ,
                     telemetry=False, kv_block_size=4).serve(a)
    ContinuousEngine(model, params, batch_size=3, max_seq=MAX_SEQ,
                     telemetry=False, kv_block_size="off").serve(b)
    for ra, rb in zip(a, b):
        assert ra.output == rb.output


# ---------------------------------------------------------------------------
# prefix cache: hit == cold, isolation, accounting


def _shared_prefix_reqs(cfg, n, shared_len=36, tail_len=6, max_new=5,
                        seed=7):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, shared_len).astype(np.int32)
    out = []
    for i in range(n):
        tail = np.random.default_rng(1000 + i).integers(
            0, cfg.vocab_size, tail_len).astype(np.int32)
        out.append(Request(i, np.concatenate([shared, tail]),
                           max_new_tokens=max_new))
    return out


def test_prefix_hit_matches_cold(dense):
    """A request served off matched prefix blocks (zero prefill compute for
    the shared span) must emit exactly the tokens a cold prefill emits."""
    cfg, model, params = dense
    warm = _shared_prefix_reqs(cfg, 4)
    cold = _shared_prefix_reqs(cfg, 4)
    ew = ContinuousEngine(model, params, batch_size=2, max_seq=64,
                          telemetry=False)
    sw = ew.serve(warm)
    ContinuousEngine(model, params, batch_size=2, max_seq=64,
                     telemetry=False, prefix_cache=False).serve(cold)
    for rw, rc in zip(warm, cold):
        assert rw.output == rc.output
    pc = sw["prefix_cache"]
    assert pc["hits"] == 3 and pc["misses"] == 1
    assert pc["hit_rate"] == pytest.approx(0.75)
    assert pc["cached_tokens"] == 3 * 32          # one 32-block per hit
    assert [r.cached_prompt_tokens for r in warm] == [0, 32, 32, 32]
    # computed tokens = total prompt - cached span
    assert sw["prefill_tokens_computed"] == \
        sw["prompt_tokens"] - pc["cached_tokens"]


def test_prefix_sharing_isolation(dense):
    """Slots decoding concurrently off the same shared prefix blocks must
    not disturb each other: same outputs as serving each request alone."""
    cfg, model, params = dense
    together = _shared_prefix_reqs(cfg, 3, max_new=6, seed=21)
    eng = ContinuousEngine(model, params, batch_size=3, max_seq=64,
                           telemetry=False)
    eng.serve(together)                      # all three share prefix blocks live
    for i in range(3):
        alone = _shared_prefix_reqs(cfg, 3, max_new=6, seed=21)[i]
        solo = ContinuousEngine(model, params, batch_size=1, max_seq=64,
                                telemetry=False, prefix_cache=False)
        solo.serve([alone])
        assert together[i].output == alone.output, \
            f"req {i}: shared-prefix decode corrupted a neighbor"


def test_prefix_cache_survives_slot_recycling(dense):
    """Trie-held blocks outlive the request that computed them: a later
    request hits the prefix after the original slot was recycled."""
    cfg, model, params = dense
    reqs = _shared_prefix_reqs(cfg, 4, seed=9)
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=64,
                           telemetry=False)       # strictly sequential slots
    stats = eng.serve(reqs)
    assert stats["prefix_cache"]["hits"] == 3
    assert stats["slots_recycled"] == 4


def test_telemetry_event_carries_cached_tokens(dense):
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=64)
    eng.serve(_shared_prefix_reqs(cfg, 3, seed=13))
    cached = [e.get("cached_tokens") for e in eng.tel.events
              if e["phase"] == "prefill" and "cached_tokens" in e]
    assert cached == [32, 32]                 # hits 2 and 3; miss has no key


# ---------------------------------------------------------------------------
# capacity finish (the advance-clamp fix)


@pytest.mark.parametrize("kv_block_size", ["auto", "off"])
def test_finish_at_capacity_not_clamp(dense, kv_block_size):
    """A budget beyond the cache finishes at capacity with every position
    written once — the old clamp silently rewrote max_seq-1 forever."""
    cfg, model, params = dense
    req = _mk_reqs(cfg, 1, plen=8, max_new=1000, seed=2)[0]
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                           telemetry=False, kv_block_size=kv_block_size)
    stats = eng.serve([req])
    assert req.finish_reason == "capacity"
    # prefill writes [0,8); 24 decode writes fill [8,32); the token sampled
    # from the last write is emitted but never written back
    assert len(req.output) == MAX_SEQ - 8 + 1
    assert stats["completed"] == 1


def test_capacity_and_length_agree_across_paths(dense):
    """Same request under paged and contiguous: identical tokens up to the
    identical capacity finish."""
    cfg, model, params = dense
    a = _mk_reqs(cfg, 2, plen=9, max_new=1000, seed=4)
    b = _mk_reqs(cfg, 2, plen=9, max_new=1000, seed=4)
    ContinuousEngine(model, params, batch_size=2, max_seq=MAX_SEQ,
                     telemetry=False).serve(a)
    ContinuousEngine(model, params, batch_size=2, max_seq=MAX_SEQ,
                     telemetry=False, kv_block_size="off").serve(b)
    for ra, rb in zip(a, b):
        assert ra.finish_reason == rb.finish_reason == "capacity"
        assert ra.output == rb.output


def test_submit_rejects_full_prompt(dense):
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                           telemetry=False)
    with pytest.raises(ValueError, match="decode position"):
        eng.submit(_mk_reqs(cfg, 1, plen=MAX_SEQ, seed=0)[0])
    eng.submit(_mk_reqs(cfg, 1, plen=MAX_SEQ - 1, max_new=50, seed=0)[0])


# ---------------------------------------------------------------------------
# page-aware admission + eviction under pressure


def test_admission_defers_on_page_budget(dense):
    """A pool sized for one slot's worth of blocks serializes admission
    (defer, not shed) even though two hardware slots are free."""
    cfg, model, params = dense
    reqs = _mk_reqs(cfg, 3, plen=17, max_new=10, seed=6)
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=MAX_SEQ,
                           telemetry=False, kv_block_size=16,
                           kv_pool_blocks=3)      # 2 usable blocks + null
    stats = eng.serve(reqs)
    assert stats["completed"] == 3 and stats["shed"] == 0
    assert stats["peak_active"] == 1              # pages, not slots, bound it
    for r in reqs:
        assert r.finish_reason == "length" and len(r.output) == 10
    assert stats["kv_pages"]["peak_used"] <= 2


def test_trie_eviction_under_pool_pressure(dense):
    """Distinct prompts through a tight pool force LRU trie eviction; every
    request still completes and the pool never leaks blocks."""
    cfg, model, params = dense
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 17).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                           telemetry=False, kv_block_size=16,
                           kv_pool_blocks=3)
    stats = eng.serve(reqs)
    assert stats["completed"] == 5
    assert stats["prefix_cache"]["evictions"] > 0
    # all slots released + trie evicted down: no block leaked
    used = stats["kv_pages"]["total_blocks"] - stats["kv_pages"]["free_blocks"]
    assert used == len(eng.prefix) == eng.pages.used_blocks()


def test_pool_reuse_is_scrubbed(dense):
    """Recycled blocks must be zero — sequential requests through a minimal
    pool match the contiguous engine exactly (stale KV would diverge)."""
    cfg, model, params = dense
    a = _mk_reqs(cfg, 4, plen=13, max_new=5, seed=8)
    b = _mk_reqs(cfg, 4, plen=13, max_new=5, seed=8)
    ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                     telemetry=False, kv_block_size=4, prefix_cache=False,
                     kv_pool_blocks=9).serve(a)
    ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                     telemetry=False, kv_block_size="off").serve(b)
    for ra, rb in zip(a, b):
        assert ra.output == rb.output


# ---------------------------------------------------------------------------
# compile budget: indirection must not retrace


def test_paged_compiles_stay_bucket_bounded(dense):
    """Distinct prompt/tail lengths + block-table remaps across slot
    recycling compile at most len(buckets) prefill executables and ONE
    decode executable — same budget as the unpaged bucketed engine."""
    cfg, model, params = dense
    rng = np.random.default_rng(14)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(2, 30))).astype(np.int32),
                    max_new_tokens=3) for i in range(8)]
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=MAX_SEQ,
                           telemetry=False)
    stats = eng.serve(reqs)
    assert stats["kv_block_size"] == 32
    assert stats["prefill_compiles"] <= len(eng.buckets)
    assert stats["decode_compiles"] == 1


# ---------------------------------------------------------------------------
# queue pricing net of expected cache hits


def test_queued_tokens_discounts_cached_span():
    q = RequestQueue()
    q.push(Request(0, np.arange(40, dtype=np.int32), max_new_tokens=7))
    q.push(Request(1, np.arange(10, dtype=np.int32), max_new_tokens=2))
    assert q.queued_tokens() == (40 + 7) + (10 + 2)
    cached = {0: 32, 1: 0}
    assert q.queued_tokens(lambda r: cached[r.req_id]) == (8 + 7) + (10 + 2)
    # a probe reporting more than the prompt never goes negative
    assert q.queued_tokens(lambda r: 100) == 7 + 2


def test_shed_estimate_prices_net_of_cache(dense):
    """TTL pricing sees the *uncached* prompt span: after warming the trie,
    the prefill work a queued request puts ahead of its successors is its
    tail only, not the whole prompt."""
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=64,
                           telemetry=False)
    eng.serve(_shared_prefix_reqs(cfg, 1, seed=17))     # warm the trie
    warm = _shared_prefix_reqs(cfg, 2, seed=17)         # 42-token prompts
    assert eng.adapter.expected_cached(warm[0]) == 32          # one 32-block cached
    seen = []
    def spy(req, ahead, ahead_prefill=0):
        seen.append(ahead_prefill)
        return False
    eng.admission.should_shed = spy
    for r in warm:
        eng.queue.push(r)
    eng._shed_stale()
    # req0 has nothing ahead; req1 sees req0's 10-token uncached tail, not
    # its gross 42-token prompt
    assert seen == [0, len(warm[0].prompt) - 32]
