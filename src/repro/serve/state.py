"""Per-slot cache/state adapters: one continuous engine, every family.

``ContinuousEngine`` used to speak three dialects — contiguous per-slot KV
tensors, a paged block pool, and (for the recurrent families) nothing at
all: SSM/hybrid and whisper could not share the batcher because right-pad
bucketing and slot recycling would corrupt carried state. This module
factors all per-slot state handling behind one protocol:

``CacheAdapter``        the interface the engine speaks: per-slot
                        alloc/free/reset, chunked prefill into one slot,
                        fused whole-batch decode, admission queries, and
                        declared capability flags (``ServingCaps`` from the
                        model registry — no more ``inspect.signature``
                        sniffing on model methods).
``PagedKVAdapter``      flat (k, v) caches behind a refcounted ``PagePool``
                        + radix prefix trie (dense/MoE/VLM transformers).
``WindowRingAdapter``   contiguous per-slot rows — the gemma3 local:global
                        window *ring* backend, doubling as the contiguous
                        fallback when paging is explicitly off.
``RecurrentStateAdapter`` per-slot recurrent-state gather/scatter/reset and
                        chunked left-to-right prefill (xlstm/zamba2/
                        mamba2/whisper): a prompt is fed through the model
                        in power-of-two chunks carrying state between them
                        (no right-pad ever touches the state), and the
                        finished batch-1 state is scattered into the slot's
                        row of the shared batch tree. Recurrent leaves put
                        the batch on *different* axes per leaf (xlstm sLSTM
                        tuples are [B, ...] while its mLSTM leaves are
                        [L, B, ...]); the adapter infers a per-leaf axes
                        tree once from two ``jax.eval_shape`` calls and
                        uses the axis-aware tree ops in ``models.common``.

Every jitted step runs through ``counting_jit`` against the engine's shared
``TraceStats`` so compile counts stay bounded and regression-gated: paged
and contiguous prefill by the bucket count, recurrent chunked prefill by
the number of distinct power-of-two chunk sizes (<= log2(max_seq), plus the
with-frames variants for audio) — never per request.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (reset_cache_slot, scatter_state_slot)
from repro.models.registry import ServingCaps, serving_caps
from repro.serve.paging import (PagePool, RadixPrefixCache,
                                resolve_kv_block_size)
from repro.serve.queue import Request
from repro.serve.step import (TraceStats, counting_jit, make_block_ops,
                              make_decode_step, make_paged_decode_step,
                              make_paged_slot_prefill,
                              make_recurrent_chunk_prefill, make_slot_prefill,
                              pad_to_bucket, pow2_chunks)
from repro.serve.step import prefill_buckets as auto_prefill_buckets

__all__ = ["PrefillOutcome", "CacheAdapter", "PagedKVAdapter",
           "WindowRingAdapter", "RecurrentStateAdapter", "make_adapter",
           "resolve_buckets"]


def resolve_buckets(spec, max_seq: int, model=None):
    """Normalize a ``prefill_buckets`` argument.

    ``"auto"``/True -> power-of-two edges up to ``max_seq``; ``None``/
    ``"off"``/False -> bucketing disabled (exact-length prefill, one
    executable per distinct length); an iterable -> explicit edges (sorted,
    deduped, capped at ``max_seq``). With a ``model``, ``"auto"`` silently
    degrades to off when the family declares ``bucketed_prefill=False``
    (``serving_caps``: right-pad would corrupt carried recurrent state);
    explicitly requested edges raise."""
    if spec in (None, False, "off", "none"):
        return None
    supported = model is None or serving_caps(model.cfg).bucketed_prefill
    if spec in (True, "auto"):
        return auto_prefill_buckets(max_seq) if supported else None
    if not supported:
        raise ValueError(
            f"family '{model.cfg.family}' declares bucketed_prefill=False: "
            "right-pad would corrupt carried recurrent state — its chunked "
            "prefill is already compile-bounded (pass prefill_buckets='off')")
    edges = sorted({min(int(b), max_seq) for b in spec if int(b) >= 1})
    if not edges:
        raise ValueError(f"no usable prefill buckets in {spec!r}")
    if edges[-1] < max_seq:
        edges.append(max_seq)     # every admissible prompt must fit a bucket
    return tuple(edges)


@dataclasses.dataclass
class PrefillOutcome:
    """What one slot prefill did. ``first_token is None`` means the backend
    could not back the prompt (paged pool dry): the adapter has already
    dropped its slot resources and the engine finishes the request with
    reason "pages"."""

    first_token: Optional[int]
    cached_tokens: int = 0     # prompt span served from the prefix cache
    computed_tokens: int = 0   # prompt tokens that actually ran


class CacheAdapter:
    """Base adapter: owns the model's per-slot serving state and every
    jitted step that touches it. The engine never inspects model methods or
    cache layouts — it calls this interface and trusts ``self.caps``.

    Lifecycle per slot: ``prefill(slot, req)`` claims the row (fresh state,
    prompt fed in), ``decode_step`` advances every row in one fused call,
    ``free_slot`` resets/releases the row the moment its request finishes —
    slot reuse without that reset is exactly what dalek-lint DLK008 flags.
    """

    kind: str = "base"

    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 buckets, caps: ServingCaps, trace_stats: TraceStats,
                 on_compile=None, greedy: bool = True):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.buckets = buckets
        self.caps = caps
        self.trace_stats = trace_stats
        self.on_compile = on_compile
        self.greedy = greedy
        self.caches = None
        # non-paged backends expose inert handles so engine property
        # aliases (`engine.pages` / `engine.prefix` / `engine.block_size`)
        # stay stable for benches and tests
        self.pages: Optional[PagePool] = None
        self.prefix: Optional[RadixPrefixCache] = None
        self.block_size: Optional[int] = None

    # -- lifecycle ----------------------------------------------------------

    def ensure_ready(self):
        """Lazy state allocation (first ``run``)."""
        raise NotImplementedError

    def prefill(self, slot_index: int, req: Request) -> PrefillOutcome:
        """Feed one request's prompt into ``slot_index`` (fresh per-slot
        state; other rows untouched) and sample its first token."""
        raise NotImplementedError

    def begin_step(self, active_slots) -> List:
        """Pre-decode bookkeeping; returns slots the backend can no longer
        back (engine finishes them with reason "pages")."""
        return []

    def decode_step(self, tokens, pos):
        """One fused decode for the whole batch; returns the [B, 1] device
        token array (the engine owns the single host sync)."""
        raise NotImplementedError

    def free_slot(self, slot_index: int):
        """Release/reset one slot's state so the next occupant starts
        clean. Must be called before ``SlotManager.release`` (DLK008)."""
        raise NotImplementedError

    # -- admission ----------------------------------------------------------

    def can_admit(self, req: Request) -> bool:
        """Head-of-line resource check (paged: worst-case pool coverage)."""
        return True

    def expected_cached(self, req: Request) -> int:
        """Prompt span a prefix cache would serve right now (probe only)."""
        return 0

    # -- observability ------------------------------------------------------

    def pool_gauges(self):
        """(free_blocks, evictable_blocks) for step gauges; (-1, -1) when
        the backend has no pool."""
        return -1, -1

    def run_stats(self) -> Dict:
        return {"kv_block_size": self.block_size}

    def reset_metrics(self):
        """Benchmark warmup reset: drop cached/shared state *statistics*
        (jit caches and buffers survive — freed slots are always
        re-prefilled before reuse)."""


class PagedKVAdapter(CacheAdapter):
    """Flat (k, v) layer caches behind a refcounted block pool with radix
    prefix sharing — today's paged path, unchanged semantics: COW on
    defensively-shared write positions, zero-on-free scrubbing, lazy block
    growth in decode, trie eviction under pool pressure."""

    kind = "paged-kv"

    def __init__(self, model, params, *, block_size: int,
                 prefix_cache: bool = True,
                 kv_pool_blocks: Optional[int] = None, **kw):
        super().__init__(model, params, **kw)
        self.block_size = block_size
        self.n_slot_blocks = self.max_seq // block_size
        n_blocks = (kv_pool_blocks if kv_pool_blocks is not None
                    else self.batch_size * self.n_slot_blocks + 1)
        self.pages = PagePool(self.batch_size, self.n_slot_blocks, n_blocks,
                              block_size)
        self.prefix = (RadixPrefixCache(block_size, self.pages)
                       if prefix_cache else None)
        self._decode = counting_jit(
            make_paged_decode_step(model, self.greedy), "decode",
            self.trace_stats, on_compile=self.on_compile)
        self._prefill_slot = counting_jit(
            make_paged_slot_prefill(model, bucketed=bool(self.buckets)),
            "prefill", self.trace_stats, on_compile=self.on_compile)
        self._zero_blocks, self._copy_block = make_block_ops(
            self.trace_stats, self.on_compile)

    def ensure_ready(self):
        if self.caches is None:
            # the "batch" axis of the cache is the POOL of blocks, each
            # block_size positions long; slots see contiguous views
            # through their block tables
            self.caches = self.model.init_cache(self.pages.n_blocks,
                                                self.block_size)

    # -- pool bookkeeping ---------------------------------------------------

    def _flush_freed(self):
        """Scrub freed blocks before any realloc. Fixed-width chunks (padded
        with the null block) keep the jitted zero-kernel at one executable."""
        pending = self.pages.drain_pending_zero()
        if not pending:
            return
        width = self.n_slot_blocks
        for i in range(0, len(pending), width):
            chunk = pending[i:i + width]
            chunk = chunk + [PagePool.NULL] * (width - len(chunk))
            self.caches = self._zero_blocks(self.caches,
                                            jnp.asarray(chunk, jnp.int32))

    def _alloc_block(self) -> Optional[int]:
        """One zeroed block, evicting cold prefix-cache entries if the free
        list is dry. Returns None only when every block is live."""
        self._flush_freed()
        blk = self.pages.alloc()
        if blk is None and self.prefix is not None:
            if self.prefix.evict(1):
                self._flush_freed()
                blk = self.pages.alloc()
        return blk

    # -- admission ----------------------------------------------------------

    def expected_cached(self, req: Request) -> int:
        if self.prefix is None:
            return 0
        return self.prefix.probe(np.asarray(req.prompt, np.int32))

    def can_admit(self, req: Request) -> bool:
        """Admit only when the pool can cover the request's worst-case
        footprint (prompt + budget, capped at max_seq) net of the blocks a
        prefix-cache hit would share. Evictable trie blocks count as
        available — ``_alloc_block`` reclaims them on demand."""
        span = min(len(req.prompt) + req.max_new_tokens, self.max_seq)
        needed = self.pages.blocks_for(span) \
            - self.expected_cached(req) // self.block_size
        available = self.pages.free_blocks()
        if self.prefix is not None:
            available += self.prefix.evictable_blocks()
        return needed <= available

    # -- lifecycle ----------------------------------------------------------

    def prefill(self, slot_index: int, req: Request) -> PrefillOutcome:
        """Map the matched prefix (zero compute), allocate blocks for the
        unmatched span, chunk-prefill the tail only, offer the full prompt
        blocks to the trie. ``first_token=None`` when the pool is dry (only
        possible with an explicitly undersized pool — ``can_admit`` covers
        the default sizing)."""
        prompt = np.asarray(req.prompt, np.int32)
        matched = (self.prefix.match(prompt)
                   if self.prefix is not None else [])
        if matched:
            self.pages.map_shared(slot_index, matched)
        start = len(matched) * self.block_size
        # back only the prompt here; decode grows the table block-by-block
        # (``ensure_writable``) so a request that stops early never claims
        # its worst-case footprint
        if not self.pages.ensure_capacity(slot_index, len(prompt),
                                          self._alloc_block):
            self.pages.release_slot(slot_index)
            return PrefillOutcome(None)
        tail = prompt[start:]
        table_row = jnp.asarray(self.pages.table_row(slot_index))
        if self.buckets:
            padded, n = pad_to_bucket(tail, self.buckets)
            next_tok, _, self.caches = self._prefill_slot(
                self.params, jnp.asarray(padded[None, :]), jnp.int32(n),
                jnp.int32(start), table_row, self.caches)
        else:
            next_tok, _, self.caches = self._prefill_slot(
                self.params, jnp.asarray(tail[None, :]), jnp.int32(start),
                table_row, self.caches)
        # dalek: allow[host-sync] first sampled token must reach the host to emit/EOS-check
        first = int(np.asarray(next_tok)[0, 0])
        if self.prefix is not None:
            self.prefix.insert(prompt, self.pages.table_row(slot_index))
        return PrefillOutcome(first, cached_tokens=start,
                              computed_tokens=len(tail))

    def begin_step(self, active_slots) -> List:
        """Back every active slot's write position before the fused step:
        fresh block on a boundary, COW if (defensively) shared, report the
        slot for a "pages" finish when the pool is dry."""
        doomed = []
        for s in active_slots:
            state, src, dst = self.pages.ensure_writable(
                s.index, s.pos, self._alloc_block)
            if state == "cow":
                self.caches = self._copy_block(
                    self.caches, jnp.int32(src), jnp.int32(dst))
            elif state == "oom":
                doomed.append(s)
        return doomed

    def decode_step(self, tokens, pos):
        tables = jnp.asarray(self.pages.tables)
        next_tok, _, self.caches = self._decode(
            self.params, tokens, pos, tables, self.caches)
        return next_tok

    def free_slot(self, slot_index: int):
        # drop the slot's block refs; blocks whose refcount hits zero queue
        # for scrubbing and are re-zeroed before any realloc, so the pool
        # stays bit-identical to a contiguous cache whose rows reset on
        # release
        self.pages.release_slot(slot_index)

    # -- observability ------------------------------------------------------

    def pool_gauges(self):
        free = self.pages.free_blocks()
        evictable = (self.prefix.evictable_blocks()
                     if self.prefix is not None else -1)
        return free, evictable

    def run_stats(self) -> Dict:
        pg = self.pages.stats.as_dict()
        pg["free_blocks"] = self.pages.free_blocks()
        out = {"kv_block_size": self.block_size, "kv_pages": pg}
        if self.prefix is not None:
            out["prefix_cache"] = self.prefix.stats.as_dict()
        return out

    def reset_metrics(self):
        if self.prefix is not None:
            # cold prefix cache: a benchmark's measured phase must not reap
            # hits the warmup planted (the warmup's *compiles* are exactly
            # what reset keeps)
            self.prefix.clear()
        self.pages.stats = type(self.pages.stats)(
            total_blocks=self.pages.stats.total_blocks)


class WindowRingAdapter(CacheAdapter):
    """Contiguous per-slot cache rows — the gemma3 local:global window
    *ring* backend (rings can't resume mid-stream, so no paging and no
    chunked prefill), doubling as the flat-cache contiguous fallback when
    paging is explicitly disabled. Slot reset zeroes the row."""

    kind = "window-ring"

    def __init__(self, model, params, **kw):
        super().__init__(model, params, **kw)
        if self.caps.kind != "window-ring":
            self.kind = "contiguous"       # flat family with paging off
        self._decode = counting_jit(make_decode_step(model, self.greedy),
                                    "decode", self.trace_stats,
                                    on_compile=self.on_compile)
        self._prefill_slot = counting_jit(
            make_slot_prefill(model, bucketed=bool(self.buckets)),
            "prefill", self.trace_stats, on_compile=self.on_compile)
        self._reset_slot = counting_jit(reset_cache_slot, "reset_slot",
                                        self.trace_stats,
                                        on_compile=self.on_compile)

    def ensure_ready(self):
        if self.caches is None:
            self.caches = self.model.init_cache(self.batch_size,
                                                self.max_seq)

    def prefill(self, slot_index: int, req: Request) -> PrefillOutcome:
        prompt = np.asarray(req.prompt, np.int32)
        if self.buckets:
            padded, n = pad_to_bucket(prompt, self.buckets)
            next_tok, _, self.caches = self._prefill_slot(
                self.params, jnp.asarray(padded[None, :]), jnp.int32(n),
                jnp.int32(slot_index), self.caches)
        else:
            next_tok, _, self.caches = self._prefill_slot(
                self.params, jnp.asarray(prompt[None, :]),
                jnp.int32(slot_index), self.caches)
        # dalek: allow[host-sync] first sampled token must reach the host to emit/EOS-check
        first = int(np.asarray(next_tok)[0, 0])
        return PrefillOutcome(first, computed_tokens=len(prompt))

    def decode_step(self, tokens, pos):
        next_tok, _, self.caches = self._decode(
            self.params, tokens, pos, self.caches)
        return next_tok

    def free_slot(self, slot_index: int):
        # recycle: zero the slot's cache rows so the next occupant starts
        # clean
        self.caches = self._reset_slot(self.caches, jnp.int32(slot_index))


class RecurrentStateAdapter(CacheAdapter):
    """Carried-state families (SSM/hybrid/encoder-decoder) in the
    continuous batcher.

    Prefill never right-pads: the prompt is decomposed into power-of-two
    chunks (largest first — its binary representation) and fed left-to-
    right through ``model.prefill`` with the state carried between chunks,
    starting from a *freshly initialized* batch-1 state template. The
    finished state is scattered wholesale into the slot's row of the
    shared batch tree — which doubles as the reset: no stale state from a
    prior occupant can survive, because every leaf row is overwritten.
    Executable count is bounded by the distinct chunk sizes
    (<= log2(max_seq), plus the frames variant for audio's first chunk),
    never by request count.

    Decode reuses the ordinary fused step: recurrent models take the whole
    state tree and a [B] position vector (position-free families ignore
    it), and every update is per-row, so batched decode is bit-exact
    against one-request-at-a-time serving (property-tested).

    Free rows keep whatever state their garbage decode writes produce; the
    next occupant's prefill overwrites every leaf row before any read, so
    that garbage is never observable.
    """

    kind = "recurrent"

    def __init__(self, model, params, **kw):
        super().__init__(model, params, **kw)
        assert not self.buckets, "recurrent prefill cannot right-pad"
        # per-leaf batch axis: recurrent trees mix [L, B, ...] and [B, ...]
        # leaves — diff two abstract shapes to find which axis is batch
        s2 = jax.eval_shape(lambda: model.init_cache(2, self.max_seq))
        s3 = jax.eval_shape(lambda: model.init_cache(3, self.max_seq))
        self._axes = jax.tree.map(
            lambda a, b: next(i for i, (x, y) in
                              enumerate(zip(a.shape, b.shape)) if x != y),
            s2, s3)
        self._fresh = None    # batch-1 freshly-initialized state template
        self._decode = counting_jit(make_decode_step(model, self.greedy),
                                    "decode", self.trace_stats,
                                    on_compile=self.on_compile)
        self._chunk = counting_jit(
            make_recurrent_chunk_prefill(model), "prefill",
            self.trace_stats, on_compile=self.on_compile)
        self._scatter = counting_jit(
            lambda caches, sub, slot: scatter_state_slot(
                caches, sub, slot, self._axes),
            "state_scatter", self.trace_stats, on_compile=self.on_compile)

    def ensure_ready(self):
        if self.caches is None:
            self.caches = self.model.init_cache(self.batch_size,
                                                self.max_seq)
            self._fresh = self.model.init_cache(1, self.max_seq)

    def prefill(self, slot_index: int, req: Request) -> PrefillOutcome:
        prompt = np.asarray(req.prompt, np.int32)
        frames = req.frames
        state = self._fresh
        offset = 0
        next_tok = None
        for size in pow2_chunks(len(prompt)):
            tokens = jnp.asarray(prompt[None, offset:offset + size])
            fr = (jnp.asarray(frames)[None] if
                  (frames is not None and offset == 0) else None)
            next_tok, _, state = self._chunk(
                self.params, tokens, fr, jnp.int32(offset), state)
            offset += size
        # scatter the finished batch-1 state into the slot's row: claims
        # AND resets the row in one write (every leaf row is overwritten)
        self.caches = self._scatter(self.caches, state,
                                    jnp.int32(slot_index))
        # dalek: allow[host-sync] first sampled token must reach the host to emit/EOS-check
        first = int(np.asarray(next_tok)[0, 0])
        return PrefillOutcome(first, computed_tokens=len(prompt))

    def decode_step(self, tokens, pos):
        next_tok, _, self.caches = self._decode(
            self.params, tokens, pos, self.caches)
        return next_tok

    def free_slot(self, slot_index: int):
        # belt-and-braces reset: scatter the fresh template into the freed
        # row (same executable as the prefill scatter). The next prefill
        # overwrites the row anyway, but a zeroed row keeps state dumps and
        # replay bit-reproducible regardless of traffic order.
        if self.caches is not None:
            self.caches = self._scatter(self.caches, self._fresh,
                                        jnp.int32(slot_index))


def make_adapter(model, params, *, batch_size: int, max_seq: int,
                 prefill_buckets="auto", kv_block_size="auto",
                 prefix_cache: bool = True,
                 kv_pool_blocks: Optional[int] = None, greedy: bool = True,
                 trace_stats: Optional[TraceStats] = None, on_compile=None):
    """Select and build the backend for ``model``'s declared capabilities.

    ``"auto"`` arguments degrade silently where the family can't honor them
    (paging/bucketing off for recurrent, paging off for window rings);
    explicit requests on an incapable family raise with the actionable
    alternative — the early error ``launch/serve.py`` surfaces."""
    caps = serving_caps(model.cfg)
    buckets = resolve_buckets(prefill_buckets, max_seq, model)
    trace_stats = trace_stats if trace_stats is not None else TraceStats()
    common = dict(batch_size=batch_size, max_seq=max_seq, buckets=buckets,
                  caps=caps, trace_stats=trace_stats, on_compile=on_compile,
                  greedy=greedy)
    block_size = resolve_kv_block_size(kv_block_size, max_seq, caps.paged_kv)
    if caps.kind == "recurrent":
        return RecurrentStateAdapter(model, params, **common)
    if block_size:
        return PagedKVAdapter(model, params, block_size=block_size,
                              prefix_cache=prefix_cache,
                              kv_pool_blocks=kv_pool_blocks, **common)
    return WindowRingAdapter(model, params, **common)
