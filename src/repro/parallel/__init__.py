from repro.parallel.sharding import LOGICAL_RULES, Sharder, spec_for
