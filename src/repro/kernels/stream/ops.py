"""Jit'd wrappers for the STREAM kernels; bytes-moved accounting included
(the benchmark derives GB/s exactly like the paper's `bandwidth` tool)."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.stream import stream as k


@functools.partial(jax.jit, static_argnames=("interpret",))
def copy(a, interpret=False):
    return k.stream_copy(a, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scale(a, x, interpret=False):
    return k.stream_scale(a, x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def add(a, b, interpret=False):
    return k.stream_add(a, b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def triad(a, b, x, interpret=False):
    return k.stream_triad(a, b, x, interpret=interpret)


def bytes_moved(op: str, a) -> int:
    n = a.size * a.dtype.itemsize
    return {"read": n, "write": n, "copy": 2 * n, "scale": 2 * n,
            "add": 3 * n, "triad": 3 * n}[op]
