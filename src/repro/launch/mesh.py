"""Production mesh construction.

Single pod: (data=16, model=16) — 256 TPU v5e chips on ICI.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is the
slow inter-pod (DCN) link, DALEK's 2.5 GbE analogue: only data-parallel
gradient reductions cross it.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    axes = ("pod", "data", "model")
    shape = (pod, data, model)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * 3)
