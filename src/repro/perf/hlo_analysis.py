"""Post-SPMD HLO analysis: collective byte accounting + roofline terms.

``cost_analysis()`` gives per-device FLOPs and HBM bytes but no collective
traffic; we parse the optimized HLO (``compiled.as_text()``) and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, splitting traffic that crosses the ``pod`` axis (slow
DCN link, DALEK's 2.5 GbE analogue) from intra-pod ICI traffic.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*(?:\},\{[^}]*)*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op: str
    result_bytes: int
    group_size: int
    crosses_pod: bool


def _parse_groups(line: str, pod_block: Optional[int]):
    """Returns (group_size, crosses_pod)."""
    m = _IOTA_RE.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        total = 1
        for d in m.group(3).split(","):
            total *= int(d)
        crosses = False
        if pod_block:
            # iota without transpose: groups are contiguous stride-1 blocks
            if not m.group(4):
                crosses = group_size > pod_block or (
                    group_size * n_groups > pod_block and group_size > 1
                    and (pod_block % group_size) != 0)
            else:
                # transposed iota: strided groups -> conservatively assume
                # they span pods when total exceeds one pod
                crosses = total > pod_block
        return group_size, crosses
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        ids = [int(x) for x in first.split(",") if x.strip()]
        size = max(len(ids), 1)
        crosses = False
        if pod_block and ids:
            crosses = (min(ids) // pod_block) != (max(ids) // pod_block)
        return size, crosses
    return 1, False


def parse_collectives(hlo_text: str, pod_block: Optional[int] = None
                      ) -> List[CollectiveStats]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        gsize, crosses = _parse_groups(line, pod_block)
        out.append(CollectiveStats(op, _type_bytes(type_str), gsize, crosses))
    return out


def collective_bytes_per_device(stats: List[CollectiveStats]) -> Dict[str, float]:
    """Per-device link traffic (bytes), ring-algorithm accounting:

    all-gather:        (g-1)/g * result
    all-reduce:        2 * (g-1)/g * result
    reduce-scatter:    (g-1) * result  (result is the scattered shard)
    all-to-all:        (g-1)/g * result
    collective-permute: result
    """
    ici = dcn = 0.0
    per_op: Dict[str, float] = {}
    for s in stats:
        g = max(s.group_size, 1)
        if s.op == "all-gather":
            b = s.result_bytes * (g - 1) / g
        elif s.op == "all-reduce":
            b = 2 * s.result_bytes * (g - 1) / g
        elif s.op == "reduce-scatter":
            b = s.result_bytes * (g - 1)
        elif s.op == "all-to-all":
            b = s.result_bytes * (g - 1) / g
        else:  # collective-permute
            b = s.result_bytes
        per_op[s.op] = per_op.get(s.op, 0.0) + b
        if s.crosses_pod:
            dcn += b
        else:
            ici += b
    return {"ici_bytes": ici, "dcn_bytes": dcn, **per_op}


def analyze(compiled, pod_block: Optional[int] = None,
            fused_attn_shapes=None) -> Dict:
    """Full analysis of a compiled executable.

    FLOPs/bytes/collectives come from the trip-count-aware HLO walker
    (``repro.perf.hlo_cost``); XLA's own cost_analysis (which counts loop
    bodies once) is kept under ``xla_*`` keys for comparison.
    """
    from repro.perf import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    walked = hlo_cost.analyze_text(text, pod_block, fused_attn_shapes)
    f32_hoist = hlo_cost.f32_hoist_artifact_bytes(text)
    return {
        "flops": walked["flops"],
        "bytes_accessed": walked["bytes_accessed"],
        "attn_score_bytes": walked.get("attn_score_bytes", 0.0),
        "f32_hoist_bytes": f32_hoist,
        "collectives": walked["collectives"],
        "collective_counts": walked["collective_counts"],
        "n_collectives": walked["n_collectives"],
        "xla_flops": float(cost.get("flops", 0.0)),
        "xla_bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
