"""GPIO region tagging (paper Sec. 4.1/4.3).

The main board has eight GPIO inputs driven by the measured node, so running
code can tag samples with the active code segment ("measure the consumption
of a specific function"). We reproduce the exact constraint: at most 8
concurrent binary channels; a tag is a named channel raised/lowered around a
code region, and samples record the set of channels high at sample time.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, List, Optional, Tuple

N_GPIO = 8


class TagBus:
    """The 8-channel GPIO bus between the node and its main board."""

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self._lock = threading.Lock()
        self._channels: Dict[str, int] = {}     # name -> gpio index
        self._high: Dict[int, str] = {}         # gpio index -> name
        self._events: List[Tuple[float, int, str, bool]] = []

    def _alloc(self, name: str) -> int:
        if name in self._channels:
            return self._channels[name]
        if len(self._channels) >= N_GPIO:
            raise RuntimeError(
                f"all {N_GPIO} GPIO tag channels in use (paper HW limit)")
        idx = next(i for i in range(N_GPIO)
                   if i not in self._channels.values())
        self._channels[name] = idx
        return idx

    def raise_(self, name: str):
        with self._lock:
            idx = self._alloc(name)
            self._high[idx] = name
            self._events.append((self._clock(), idx, name, True))

    def lower(self, name: str):
        with self._lock:
            idx = self._channels.get(name)
            if idx is not None and idx in self._high:
                del self._high[idx]
                self._events.append((self._clock(), idx, name, False))

    def active_at(self, t: float) -> Tuple[str, ...]:
        """Tags high at time t (replays the event log)."""
        high: Dict[int, str] = {}
        for et, idx, name, up in self._events:
            if et > t:
                break
            if up:
                high[idx] = name
            else:
                high.pop(idx, None)
        return tuple(sorted(high.values()))

    def active_now(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._high.values()))

    @contextlib.contextmanager
    def tag(self, name: str):
        """``with bus.tag("fwd"): ...`` — energy attribution for a region."""
        self.raise_(name)
        try:
            yield
        finally:
            self.lower(name)

    def intervals(self, name: str) -> List[Tuple[float, Optional[float]]]:
        """(start, end) intervals for a tag; end=None if still high."""
        out: List[Tuple[float, Optional[float]]] = []
        start = None
        for et, _, n, up in self._events:
            if n != name:
                continue
            if up and start is None:
                start = et
            elif not up and start is not None:
                out.append((start, et))
                start = None
        if start is not None:
            out.append((start, None))
        return out
