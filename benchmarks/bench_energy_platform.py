"""Paper Sec. 4: energy measurement platform throughput + resolution.

Derived columns assert the platform's headline numbers: 1000 SPS per probe,
milliwatt resolution, 12-probe aggregation, tag attribution overhead — and
the comparison against GRID'5000 (~50 SPS @ 0.1 W).
"""
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.mainboard import MainBoard
from repro.core.probe import MILLIWATT, REPORT_SPS, Probe, ProbeConfig, read_vectorized


def run():
    mb = MainBoard()
    for i in range(12):
        mb.attach(Probe(lambda t: 80.0 + 10 * np.sin(t),
                        ProbeConfig(probe_id=i)))
    t = time_fn(lambda: mb.read_samples(0.05), warmup=1, iters=3)
    n_samples = 12 * int(0.05 * REPORT_SPS)
    emit("energy/mainboard_12probe", t,
         f"{n_samples / t:.0f}samples/s_processed;hw_rate={REPORT_SPS}SPS")

    t = time_fn(lambda: read_vectorized(lambda x: 95.0, 0.0, 10.0),
                warmup=1, iters=3)
    emit("energy/probe_vectorized_10s", t,
         f"{10 * REPORT_SPS / t:.0f}samples/s;res={MILLIWATT * 1e3:.0f}mW")

    with mb.tags.tag("fwd"):
        samples = mb.read_samples(0.02)[0]
    t = time_fn(lambda: MainBoard.energy_by_tag(samples), warmup=1, iters=5)
    emit("energy/tag_attribution", t, f"grid5000_ratio={REPORT_SPS / 50:.0f}x")


if __name__ == "__main__":
    run()
