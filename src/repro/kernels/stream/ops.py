"""Jit'd wrappers for the STREAM kernels; bytes-moved accounting included
(the benchmark derives GB/s exactly like the paper's `bandwidth` tool)."""
from repro.core.tracing import TraceStats, counting_jit
from repro.kernels.stream import stream as k

#: module-level compile accounting — bench_bandwidth reports these counts
stats = TraceStats()


def _copy(a, interpret=False):
    return k.stream_copy(a, interpret=interpret)


def _scale(a, x, interpret=False):
    return k.stream_scale(a, x, interpret=interpret)


def _add(a, b, interpret=False):
    return k.stream_add(a, b, interpret=interpret)


def _triad(a, b, x, interpret=False):
    return k.stream_triad(a, b, x, interpret=interpret)


copy = counting_jit(_copy, "stream/copy", stats,
                    static_argnames=("interpret",))
scale = counting_jit(_scale, "stream/scale", stats,
                     static_argnames=("interpret",))
add = counting_jit(_add, "stream/add", stats,
                   static_argnames=("interpret",))
triad = counting_jit(_triad, "stream/triad", stats,
                     static_argnames=("interpret",))


def bytes_moved(op: str, a) -> int:
    n = a.size * a.dtype.itemsize
    return {"read": n, "write": n, "copy": 2 * n, "scale": 2 * n,
            "add": 3 * n, "triad": 3 * n}[op]
