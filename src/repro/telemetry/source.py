"""Power sources: what the probes measure.

A :class:`PowerSource` is the ``power(t) -> W`` callable a probe samples.
On DALEK hardware that is the physical node behind the INA228; here it is a
model. Three standard sources cover every consumer in the repo:

``ModelSource``    wraps ``core.energy.ServePowerModel`` — phase-aware
                   roofline/DVFS traces stretched onto measured wall-clock
                   windows (the serving engines);
``MutableSource``  a host-settable constant — the training loop updates it
                   once per step from the utilization model (replaces the
                   old closure-over-``self._power_w`` lambda);
``TraceSource``    replays recorded ``(t, watts)`` arrays (zero-order hold),
                   e.g. a previously captured ``SampleBlock`` or a
                   ``repro.tracestore`` stream.

All three evaluate on whole numpy timestamp arrays, which is what lets the
columnar probe path vectorize end to end.

Sampling a ``TraceSource`` past the end of its recording raises
:class:`TraceExhausted` by default — a replay that silently flat-lines
after the data runs out corrupts every downstream energy number. Pass
``on_exhausted="loop"`` to wrap around explicitly, ``"hold"`` to
zero-order-hold the final report, or ``"fill"`` to fall back to ``fill_w``.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.energy import ServePowerModel


class TraceExhausted(RuntimeError):
    """A ``TraceSource`` was sampled past the end of its recording."""


@runtime_checkable
class PowerSource(Protocol):
    """power(t) in watts; ``t`` may be a float or a numpy array."""

    def __call__(self, t): ...


class MutableSource:
    """Constant power the host updates between sampling windows."""

    def __init__(self, watts: float = 0.0):
        self._watts = float(watts)

    def set(self, watts: float):
        self._watts = float(watts)

    @property
    def watts(self) -> float:
        return self._watts

    def __call__(self, t):
        return self._watts


class ModelSource:
    """Phase-aware power from a :class:`ServePowerModel`.

    Between steps the node idles; during a step the host installs the
    model's trace for that step's token count and measured duration
    (``set_step``), anchored at the step's start time on the session clock.
    """

    def __init__(self, power_model: ServePowerModel):
        self.pm = power_model
        self._trace = None
        self._t0 = 0.0

    def set_step(self, n_tokens: int, wall_s: float, t0: float = 0.0):
        """Install the trace for a step of ``n_tokens`` over ``wall_s``
        seconds starting at absolute time ``t0``."""
        self._trace = self.pm.trace(n_tokens, wall_s)
        self._t0 = t0

    def clear(self):
        self._trace = None

    def __call__(self, t):
        if self._trace is None:
            idle = self.pm.idle_power_w()
            return np.full(np.shape(t), idle) if np.ndim(t) else idle
        return self._trace(t - self._t0)


class TraceSource:
    """Replay of a recorded power trace (zero-order hold: the report at
    ``t_i`` is the average power over ``(t_{i-1}, t_i]``).

    ``on_exhausted`` picks the out-of-range behavior for times past the
    final report:

    ``"raise"``  (default) raise :class:`TraceExhausted` — replays must not
                 silently extrapolate energy that was never recorded;
    ``"loop"``   wrap modulo the final timestamp (the trace is treated as
                 one period anchored at t=0, e.g. a steady-state profile);
    ``"hold"``   zero-order-hold the final report forever;
    ``"fill"``   report ``fill_w`` past the end.
    """

    MODES = ("raise", "loop", "hold", "fill")

    def __init__(self, t: np.ndarray, watts: np.ndarray,
                 fill_w: float = 0.0, on_exhausted: str = "raise"):
        if on_exhausted not in self.MODES:
            raise ValueError(f"on_exhausted={on_exhausted!r} "
                             f"(expected one of {self.MODES})")
        t = np.asarray(t, np.float64)
        order = np.argsort(t, kind="stable")
        self._t = t[order]
        self._w = np.asarray(watts, np.float64)[order]
        self._fill = float(fill_w)
        self._mode = on_exhausted

    @classmethod
    def from_block(cls, block, fill_w: float = 0.0,
                   on_exhausted: str = "raise") -> "TraceSource":
        return cls(block.t, block.watts, fill_w, on_exhausted)

    @property
    def t_end(self) -> float:
        """Timestamp of the final report (0.0 for an empty trace)."""
        return float(self._t[-1]) if self._t.shape[0] else 0.0

    def __len__(self) -> int:
        return int(self._t.shape[0])

    def __call__(self, t):
        if self._t.shape[0] == 0:
            if self._mode == "raise":
                raise TraceExhausted("TraceSource has no recorded samples")
            return np.full(np.shape(t), self._fill) if np.ndim(t) else self._fill
        t_arr = np.asarray(t, np.float64)
        end = self._t[-1]
        if self._mode == "raise" and np.any(t_arr > end):
            raise TraceExhausted(
                f"sampled t={float(np.max(t_arr)):.6f}s past the recording "
                f"end ({float(end):.6f}s); pass on_exhausted='loop' to wrap "
                f"or 'hold'/'fill' to extrapolate explicitly")
        if self._mode == "loop" and end > 0:
            t_arr = np.where(t_arr > end, np.mod(t_arr, end), t_arr)
        idx = np.searchsorted(self._t, t_arr, side="left")
        out = self._w[np.clip(idx, 0, self._w.shape[0] - 1)]
        if self._mode == "fill":
            out = np.where(idx >= self._t.shape[0], self._fill, out)
        return out if np.ndim(t) else float(out)


def constant(watts: float) -> MutableSource:
    """Convenience: a fixed-power source."""
    return MutableSource(watts)


__all__ = ["PowerSource", "MutableSource", "ModelSource", "TraceSource",
           "TraceExhausted", "constant", "ServePowerModel"]
