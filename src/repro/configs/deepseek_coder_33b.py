"""deepseek-coder-33b — llama-arch dense [arXiv:2401.14196; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128,
    source="arXiv:2401.14196",
)

SMOKE_CONFIG = CONFIG.replace(
    name="deepseek-coder-33b-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=16,
)
