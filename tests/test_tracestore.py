"""Trace store (`repro.tracestore`): .dkt round-trip fidelity (property:
SampleBlock -> file -> SampleBlock bit-exact, including empty blocks and
recycled >8-tag channels), time-indexed reads, deterministic replay
(same trace -> identical ReplayReport twice), and live-run attribution
reproduction (replayed per-request joules == live engine's, the paper's
"regression-test policies against recorded power" workflow)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # container without the test extra: the seeded
    HAVE_HYPOTHESIS = False  # fallback below still covers the round trip

from repro.cluster.topology import dalek_topology
from repro.core.probe import ProbeConfig
from repro.core.scheduler import ThroughputStats
from repro.serve.queue import AdmissionController
from repro.telemetry import MonitorSession, MutableSource, SampleBlock
from repro.tracestore import (ClusterRecorder, ReplayRequest, TraceFormatError,
                              TraceReader, TraceWriter, replay, replay_policy)


def assert_block_equal(a: SampleBlock, b: SampleBlock):
    for field in ("t", "volts", "watts", "dt"):
        va, vb = getattr(a, field), getattr(b, field)
        assert va.dtype == vb.dtype == np.float64
        assert np.array_equal(va, vb), field
    assert np.array_equal(a.bits, b.bits)
    assert b.bits.dtype == np.uint8
    assert np.array_equal(a.seg_bounds, b.seg_bounds)
    assert a.seg_maps == b.seg_maps
    assert a.n_avg == b.n_avg


# ---------------------------------------------------------------------------
# format round trip


def random_block(rng: np.random.Generator, n: int) -> SampleBlock:
    """Random block: empty when n=0, and more distinct tag names than the
    8 GPIO lines (recycled channels: the same line maps to different names
    in different segments)."""
    if n == 0:
        return SampleBlock.empty()
    t = np.sort(rng.uniform(0.0, 10.0, n))
    k = int(rng.integers(1, min(n, 5) + 1))
    cuts = sorted({0, n, *map(int, rng.integers(1, n, k - 1))}) if n > 1 \
        else [0, n]
    names = [f"region_{i}" for i in range(12)]       # 12 names, 8 lines
    maps = tuple(
        {int(line): names[int(rng.integers(0, len(names)))]
         for line in rng.choice(8, size=int(rng.integers(0, 5)),
                                replace=False)}
        for _ in range(len(cuts) - 1))
    return SampleBlock(
        t=t, volts=np.full(n, 20.0),
        watts=rng.uniform(0.0, 240.0, n),
        dt=np.full(n, 1e-3),
        bits=rng.integers(0, 256, n).astype(np.uint8),
        seg_bounds=np.asarray(cuts, np.int64), seg_maps=maps)


def _round_trip(path, rng, ns, n_streams):
    blocks = [random_block(rng, n) for n in ns]
    assign = [int(rng.integers(0, n_streams)) for _ in blocks]
    with TraceWriter(path) as w:
        sids = [w.add_stream(f"s{i}", node=f"n{i}", sps=1000.0)
                for i in range(n_streams)]
        for sid_i, block in zip(assign, blocks):
            w.append(sids[sid_i], block)
    with TraceReader(path) as r:
        per_stream = {sid: list(r.blocks(sid)) for sid in sids}
    for sid_i, block in zip(assign, blocks):
        assert_block_equal(block, per_stream[sids[sid_i]].pop(0))
    assert all(not rest for rest in per_stream.values())


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           ns=st.lists(st.integers(0, 40), min_size=1, max_size=4),
           n_streams=st.integers(1, 2))
    def test_dkt_round_trip_bit_exact(tmp_path_factory, seed, ns, n_streams):
        path = tmp_path_factory.mktemp("dkt") / "roundtrip.dkt"
        _round_trip(path, np.random.default_rng(seed), ns, n_streams)


def test_dkt_round_trip_bit_exact_seeded(tmp_path):
    """Seeded sweep of the same property (runs without hypothesis), pinning
    the empty-block and single-sample edge cases."""
    rng = np.random.default_rng(7)
    cases = [[0], [1], [0, 0], [40, 0, 13]]
    cases += [[int(n) for n in rng.integers(0, 40, 3)] for _ in range(10)]
    for case, ns in enumerate(cases):
        _round_trip(tmp_path / f"rt{case}.dkt", rng, ns,
                    n_streams=1 + case % 2)


def test_dkt_round_trips_recycled_session_channels(tmp_path):
    """End-to-end: a session that cycles through 3x the GPIO line budget
    round-trips with every segment map (and thus every resolved tag) intact."""
    src = MutableSource(42.0)
    session = MonitorSession(src, probe_cfg=ProbeConfig(noise_w=0.0))
    for i in range(24):                        # 24 distinct names, 8 lines
        with session.region(f"phase_{i}"):
            session.sample(0.004)
    live = session.block()
    path = tmp_path / "recycled.dkt"
    with TraceWriter(path) as w:
        sid = w.add_stream("n/p0")
        for b in session.blocks():
            w.append(sid, b)
    with TraceReader(path) as r:
        back = r.read(sid)
        assert len(r.tags) == 24
    assert_block_equal(live, back)
    assert live.energy_by_tag() == back.energy_by_tag()
    # the lazy Sample view resolves identical string tuples
    assert [s.tags for s in back.samples()] == [s.tags for s in live.samples()]


def test_reader_time_seek_and_trim(tmp_path):
    src = MutableSource(100.0)
    session = MonitorSession(src, probe_cfg=ProbeConfig(noise_w=0.0))
    for _ in range(10):
        session.sample(0.05)                   # 10 chunks, 50 ms each
    path = tmp_path / "seek.dkt"
    with TraceWriter(path) as w:
        sid = w.add_stream("n/p0")
        for b in session.blocks():
            w.append(sid, b)
    with TraceReader(path) as r:
        assert r.n_samples(sid) == 500
        # seek lands on the chunk covering t (footer index only)
        k = r.seek(sid, 0.26)
        assert r.chunks(sid)[k].t0 <= 0.26 <= r.chunks(sid)[k].t1
        full = r.read(sid)
        part = r.read(sid, t0=0.101, t1=0.3)
        expected = int(((full.t >= 0.101) & (full.t <= 0.3)).sum())
        assert part.n == expected and 198 <= expected <= 201
        assert part.t[0] >= 0.101 and part.t[-1] <= 0.3
        assert part.energy_j() == pytest.approx(100.0 * 0.2, rel=2e-2)


def test_empty_chunk_between_windows_keeps_seek_sorted(tmp_path):
    """An empty window (sub-grid sample) records t0=t1=0.0; the seek index
    must stay sorted so reads after it don't silently drop samples."""
    src = MutableSource(100.0)
    session = MonitorSession(src, probe_cfg=ProbeConfig(noise_w=0.0))
    session.sample(0.05)
    session.sample(0.0004)                     # sub-grid: empty block
    session.sample(0.05)
    assert [b.n for b in session.blocks()] == [50, 0, 50]
    path = tmp_path / "gap.dkt"
    with TraceWriter(path) as w:
        sid = w.add_stream("n/p0")
        for b in session.blocks():
            w.append(sid, b)
    with TraceReader(path) as r:
        part = r.read(sid, t0=0.04)
        full = r.read(sid)
        assert part.n == int((full.t >= 0.04).sum())   # nothing dropped
        assert r.seek(sid, 0.045) == 0


def test_window_spanning_drain_raises():
    src = MutableSource(10.0)
    session = MonitorSession(src, probe_cfg=ProbeConfig(noise_w=0.0))
    session.sample(0.01)
    with pytest.raises(RuntimeError, match="drained"):
        with session.window() as w:
            session.sample(0.01)
            session.drain()                    # recorder flush mid-window
            session.sample(0.01)
            w.report()
    # windows opened after the drain work normally
    with session.window() as w:
        session.sample(0.02)
    assert w.report().n_samples == 20


def test_reader_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.dkt"
    bad.write_bytes(b"not a trace at all")
    with pytest.raises(TraceFormatError):
        TraceReader(bad)
    trunc = tmp_path / "trunc.dkt"
    with TraceWriter(trunc) as w:
        sid = w.add_stream("s")
        w.append(sid, SampleBlock.empty())
    data = trunc.read_bytes()
    trunc.write_bytes(data[:-3])               # clip the trailer
    with pytest.raises(TraceFormatError):
        TraceReader(trunc)


# ---------------------------------------------------------------------------
# recording + deterministic replay


@pytest.fixture(scope="module")
def recorded_trace(tmp_path_factory):
    """A short 2-node recording off the paper topology (one probe per chip,
    shared clock, deterministic synthetic power)."""
    topo = dalek_topology()
    nodes = ["az5-a890m-0", "az5-a890m-1"]
    path = tmp_path_factory.mktemp("trace") / "cluster.dkt"
    with ClusterRecorder(topo, path, nodes=nodes) as rec:
        for step in range(8):
            t = rec.cursor
            for j, name in enumerate(nodes):
                node = topo.nodes[name]
                u = 0.5 + 0.5 * np.sin(5.0 * t + j)
                rec.set_power(name, [d.idle_w + (d.tdp_w - d.idle_w) * u
                                     for d in node.spec.devices])
            rec.sample(0.05)
    return path, topo, nodes


def test_cluster_recorder_streams(recorded_trace):
    path, topo, nodes = recorded_trace
    with TraceReader(path) as r:
        assert [s["node"] for s in r.streams] == \
            [n for n in nodes for _ in topo.nodes[n].spec.devices]
        for s in r.streams:
            assert s["sps"] == 1000.0          # 2 chips/node: no I2C degrade
            assert r.n_samples(s["id"]) == 400  # 8 windows x 50 ms x 1 kHz
        assert r.meta["kind"] == "cluster"
        assert r.meta["duration_s"] == pytest.approx(0.4)


def test_replay_policy_deterministic(recorded_trace):
    path, _, _ = recorded_trace
    wl = [ReplayRequest(i, max_new_tokens=8, ttl_s=0.1, arrival_s=0.02 * i)
          for i in range(6)]
    policies = lambda: {                               # noqa: E731
        "baseline": None,
        "strict": AdmissionController(stats=ThroughputStats(),
                                      max_slots_fn=lambda b: 1)}
    a = replay(path, workload=wl, policies=policies(), batch_size=2,
               step_s=0.01)
    b = replay(path, workload=wl, policies=policies(), batch_size=2,
               step_s=0.01)
    assert a == b                      # same trace -> identical ReplayReport
    assert a.result("baseline").tokens > 0
    assert a.result("baseline").attributed_j > 0
    # the strict policy admits less -> sheds more under TTL pressure
    d = a.deltas("baseline", "strict")
    assert d["shed"] >= 0
    # injectable max_slots hook actually constrained concurrency
    assert a.result("strict").completed <= a.result("baseline").completed


def test_replay_policy_energy_conserved(recorded_trace):
    """Attributed joules never exceed the recorded trace energy, and with a
    work-conserving policy the active-window share adds up exactly."""
    path, _, _ = recorded_trace
    wl = [ReplayRequest(i, max_new_tokens=4) for i in range(4)]
    with TraceReader(path) as r:
        res = replay_policy(r, wl, batch_size=4, step_s=0.01)
        total = sum(r.read(s["id"]).energy_j() for s in r.streams)
    assert res.attributed_j <= total + 1e-9
    assert res.attributed_j == pytest.approx(
        sum(j for _, j in res.per_request_j))


def test_replay_cluster_jobs_debit_recorded_power(recorded_trace):
    path, topo, nodes = recorded_trace
    rep = replay(path, topo=topo,
                 cluster_jobs=[{"user": "u1", "partition": "az5-a890m",
                                "n_nodes": 2, "duration_s": 0.2,
                                "submit_s": 0.0}])
    assert len(rep.cluster_jobs) == 1
    job = rep.cluster_jobs[0]
    assert job.state == "DONE"
    assert job.energy_j > 0            # measured joules, not TDP guesses


# ---------------------------------------------------------------------------
# live engine -> record -> replay attribution (the acceptance bar)


def test_engine_attribution_replays_exactly(tmp_path):
    jax = pytest.importorskip("jax")
    from repro import configs
    from repro.models import build_model
    from repro.serve.engine import ContinuousEngine, Request
    from repro.tracestore import record_engine, replay_attribution

    cfg = configs.get_smoke("granite-20b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3 + (i % 3) * 3) for i in range(5)]
    eng = ContinuousEngine(model, params, batch_size=3, max_seq=48)
    eng.serve(reqs)

    path = tmp_path / "serve.dkt"
    record_engine(eng.tel, path)
    with TraceReader(path) as r:
        replayed = replay_attribution(r)
    with TraceReader(path) as r:
        replayed_again = replay_attribution(r)

    live = {req.req_id: req.energy_j for req in reqs}
    assert set(replayed) == {rid for rid, j in live.items() if j > 0}
    for rid, j in replayed.items():
        assert abs(j - live[rid]) < 1e-6          # acceptance: within 1e-6 J
    assert replayed == replayed_again              # deterministic replay
