"""zamba2-1.2b — Mamba2 backbone + shared attention block [arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_conv_width=4,
    attn_every=6,          # shared attention block every 6 mamba layers
    subquadratic=True,
    source="arXiv:2411.15242",
)

SMOKE_CONFIG = CONFIG.replace(
    name="zamba2-1.2b-smoke", num_layers=6, d_model=128, num_heads=8,
    num_kv_heads=8, d_ff=256, vocab_size=512, head_dim=16,
    ssm_state=16, attn_every=3,
)
