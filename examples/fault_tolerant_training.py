"""Fault-tolerant elastic training: inject node failures mid-run; the
orchestrator shrinks the worker set, restores the last committed checkpoint,
and finishes. Demonstrates the checkpoint-restart + elastic re-mesh path a
1000-node deployment depends on.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import sys, pathlib, tempfile
sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import ckpt
from repro.core.tracing import counting_jit
from repro.cluster.fault import ElasticTrainOrchestrator, FailureInjector
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import StepConfig, TrainState, make_train_step


def main():
    cfg = configs.get_smoke("qwen3-32b")
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=4))
    ckpt_dir = tempfile.mkdtemp()
    sessions = {}

    def build(n_workers):
        model = build_model(cfg, q_block=16)
        params, _ = model.init(jax.random.key(0))
        state = TrainState(params, init_opt_state(params))
        step = counting_jit(make_train_step(model, OptConfig(lr=1e-3),
                                            StepConfig()),
                            "fault_example_train_step", donate_argnums=(0,))
        sessions["cur"] = {"state": state, "step_fn": step, "workers": n_workers}
        print(f"  [build] mesh rebuilt for {n_workers} workers")
        return sessions["cur"]

    def restore(sess, step):
        steps = ckpt.valid_steps(ckpt_dir)
        if not steps:
            return 0
        sess["state"], manifest = ckpt.restore(sess["state"], ckpt_dir)
        print(f"  [restore] resumed from step {manifest['step']}")
        return manifest["step"]

    def train_chunk(sess, start, n):
        st = sess["state"]
        for i in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            st, m = sess["step_fn"](st, batch)
        sess["state"] = st
        return start + n

    def save(sess, step):
        ckpt.save(sess["state"], ckpt_dir, step)

    failures = FailureInjector(mtbf_s=40.0, seed=3).schedule(["w1"], 100.0)
    print(f"injected failures at t={[round(t,1) for t,_ in failures]}")
    orch = ElasticTrainOrchestrator(build=build, restore=restore,
                                    train_chunk=train_chunk, save=save,
                                    ckpt_every=10, min_workers=1)
    st = orch.run(total_steps=40, initial_workers=4,
                  failure_events=failures, step_time_s=1.0)
    print(f"finished: step={st.step}, restarts={st.restarts}, "
          f"lost+redone steps={st.lost_steps}, final workers={st.n_workers}")


if __name__ == "__main__":
    main()
