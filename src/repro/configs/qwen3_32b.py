"""qwen3-32b — dense, GQA + qk_norm [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128,
    qk_norm=True,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-32b-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=16,
)
