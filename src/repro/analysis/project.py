"""Whole-program layer: :class:`ProjectIndex` + function summaries.

Module-local rules stop at a function boundary — the exact bug classes the
repo keeps fixing by hand escape them (a jitted result synced inside a
helper, a pool block handed to a function that forgets to free it). This
module parses the full source tree once (content-hash AST cache), resolves
imports and aliases to fully-qualified symbols, builds a call graph, and
propagates per-function summaries to a fixpoint:

* ``syncs_params``    — which parameters the function copies to host
                        (``np.asarray``/``.item()``/``int()`` …, or passing
                        them to a callee that does);
* ``returns_device``  — the return value holds a device array (result of a
                        jit-wrapped call, directly or transitively);
* ``consumes_params`` — a block/span handle parameter is stored, returned,
                        entered with ``with``, freed/ended, or forwarded to
                        a consuming callee.

Interprocedural rules (DLK009 interproc-host-sync, DLK011
ownership-handoff, DLK012 unguarded-shared-state) read these through
``ctx.project``. ``analyze_source`` attaches a one-module index so the
rules also run in single-file mode; ``analyze_project`` builds the full
index over every path. All output is deterministic regardless of file
discovery order: contexts are sorted by path, the fixpoint iterates
functions in (path, line) order, and call-site tables are built from the
sorted context list.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, ModuleContext, check_module,
                                 iter_py_files, parse_cached, qualname,
                                 root_name, select_rules)
from repro.analysis.rules_host import _sync_call

#: ``h.<meth>()`` forms that settle a block/span handle's ownership
CONSUME_METHODS = {"end", "close", "free", "release"}

#: fixpoint ceiling — summaries are monotone over a call graph whose
#: realistic depth is far below this; the cap only bounds pathological cycles
_MAX_ROUNDS = 10


def _module_names(path: str) -> List[str]:
    """Dotted names this file answers to, most canonical first.

    The canonical name walks up the ``__init__.py`` chain
    (``src/repro/serve/engine.py`` → ``repro.serve.engine``); a
    path-derived ``<parent>.<stem>`` alias covers script-style imports
    (``benchmarks.bench_serving``), and the bare stem covers
    ``import engine`` siblings.
    """
    p = Path(path)
    stem = p.stem
    names: List[str] = []
    pkg_parts = [] if stem == "__init__" else [stem]
    cur = p.parent
    try:
        while cur.name and (cur / "__init__.py").exists():
            pkg_parts.insert(0, cur.name)
            cur = cur.parent
    except OSError:
        pass
    if pkg_parts:
        names.append(".".join(pkg_parts))
    if stem == "__init__":
        if p.parent.name and p.parent.name not in names:
            names.append(p.parent.name)
    else:
        if p.parent.name:
            alias = f"{p.parent.name}.{stem}"
            if alias not in names:
                names.append(alias)
        if stem not in names:
            names.append(stem)
    return names or [stem]


def _import_table(ctx: ModuleContext) -> Dict[str, str]:
    """local binding -> fully-qualified dotted target."""
    table: Dict[str, str] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    table[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    table[head] = head
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:
                base = ctx.module_name.split(".")[:-node.level]
                mod = ".".join(base + ([node.module] if node.module else []))
            for a in node.names:
                if a.name == "*":
                    continue
                target = f"{mod}.{a.name}" if mod else a.name
                table[a.asname or a.name] = target
    return table


@dataclasses.dataclass
class _FuncInfo:
    """One addressable function or method in the project."""
    fq: str                                   # canonical dotted name
    ctx: ModuleContext
    node: ast.FunctionDef
    class_node: Optional[ast.ClassDef] = None


@dataclasses.dataclass
class FunctionSummary:
    """Interprocedural facts about one function, built to a fixpoint."""
    params: Tuple[str, ...]
    syncs_params: Set[int] = dataclasses.field(default_factory=set)
    consumes_params: Set[int] = dataclasses.field(default_factory=set)
    returns_device: bool = False
    #: param index -> human-readable description of the sync site
    sync_sites: Dict[int, str] = dataclasses.field(default_factory=dict)

    def facts(self):
        return (frozenset(self.syncs_params),
                frozenset(self.consumes_params), self.returns_device)


class ProjectIndex:
    """Symbols, call resolution, and function summaries for a set of
    modules. Attaches itself to every context as ``ctx.project``."""

    def __init__(self, contexts: Sequence[ModuleContext]):
        self.contexts: List[ModuleContext] = sorted(
            contexts, key=lambda c: c.path)
        self.modules: Dict[str, ModuleContext] = {}
        self._aliases: Dict[str, List[str]] = {}
        for ctx in self.contexts:
            aliases = _module_names(ctx.path)
            ctx.module_name = aliases[0]
            ctx.project = self
            self._aliases[ctx.path] = aliases
            for name in aliases:
                self.modules.setdefault(name, ctx)
        for ctx in self.contexts:
            ctx.import_table = _import_table(ctx)

        #: dotted name (under every module alias) -> function info
        self.symbols: Dict[str, _FuncInfo] = {}
        #: dotted name -> (ctx, ClassDef)
        self.classes: Dict[str, Tuple[ModuleContext, ast.ClassDef]] = {}
        self._infos: List[_FuncInfo] = []
        for ctx in self.contexts:
            self._index_module(ctx)

        #: method name -> call sites ``<recv>.<name>(...)`` across all
        #: non-test modules (DLK012's guarded-call-site analysis)
        self.attr_calls: Dict[str, List[Tuple[ModuleContext, ast.Call]]] = {}
        for ctx in self.contexts:
            if ctx.is_test:
                continue
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute):
                    self.attr_calls.setdefault(
                        node.func.attr, []).append((ctx, node))

        self.summaries: Dict[str, FunctionSummary] = self._fixpoint()

    # -- construction --------------------------------------------------------

    @classmethod
    def from_paths(cls, paths: Iterable[str]
                   ) -> Tuple["ProjectIndex", List[Finding]]:
        contexts: List[ModuleContext] = []
        errors: List[Finding] = []
        seen: Set[str] = set()
        for file in iter_py_files(paths):
            try:
                resolved = str(file.resolve())
            except OSError:
                resolved = str(file)
            if resolved in seen:
                continue
            seen.add(resolved)
            posix = file.as_posix()
            try:
                source = file.read_text()
            except (OSError, UnicodeDecodeError) as e:
                errors.append(Finding(
                    code="DLK000", rule="parse-error", path=posix,
                    line=1, col=0, message=f"could not read: {e}"))
                continue
            try:
                tree = parse_cached(source)
            except SyntaxError as e:
                errors.append(Finding(
                    code="DLK000", rule="parse-error", path=posix,
                    line=e.lineno or 1, col=e.offset or 0,
                    message=f"could not parse: {e.msg}"))
                continue
            contexts.append(ModuleContext(posix, source, tree))
        return cls(contexts), errors

    def _index_module(self, ctx: ModuleContext):
        canon = ctx.module_name
        aliases = self._aliases[ctx.path]
        for stmt in ctx.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = _FuncInfo(f"{canon}.{stmt.name}", ctx, stmt)
                self._infos.append(info)
                for alias in aliases:
                    self.symbols.setdefault(f"{alias}.{stmt.name}", info)
            elif isinstance(stmt, ast.ClassDef):
                for alias in aliases:
                    self.classes.setdefault(f"{alias}.{stmt.name}",
                                            (ctx, stmt))
                for meth in stmt.body:
                    if not isinstance(meth, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                        continue
                    info = _FuncInfo(f"{canon}.{stmt.name}.{meth.name}",
                                     ctx, meth, class_node=stmt)
                    self._infos.append(info)
                    for alias in aliases:
                        self.symbols.setdefault(
                            f"{alias}.{stmt.name}.{meth.name}", info)

    # -- symbol / call resolution --------------------------------------------

    def _candidates(self, ctx: ModuleContext, dotted: str) -> List[str]:
        parts = dotted.split(".")
        cands = []
        target = ctx.import_table.get(parts[0])
        if target:
            cands.append(".".join([target] + parts[1:]))
        cands.append(f"{ctx.module_name}.{dotted}")
        cands.append(dotted)
        return cands

    def _lookup_func(self, ctx: ModuleContext,
                     dotted: str) -> Optional[_FuncInfo]:
        for cand in self._candidates(ctx, dotted):
            info = self.symbols.get(cand)
            if info is not None:
                return info
        return None

    def _lookup_class(self, ctx: ModuleContext, dotted: str
                      ) -> Optional[Tuple[ModuleContext, ast.ClassDef]]:
        for cand in self._candidates(ctx, dotted):
            hit = self.classes.get(cand)
            if hit is not None:
                return hit
        return None

    def _method(self, ctx: ModuleContext, class_node: ast.ClassDef,
                meth: str, _seen=None) -> Optional[_FuncInfo]:
        """Look up a method on a class, following base classes."""
        _seen = _seen if _seen is not None else set()
        key = (ctx.path, class_node.name)
        if key in _seen:
            return None
        _seen.add(key)
        for stmt in class_node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == meth:
                return self.symbols.get(
                    f"{ctx.module_name}.{class_node.name}.{meth}")
        for base in class_node.bases:
            qn = qualname(base)
            if not qn:
                continue
            resolved = self._lookup_class(ctx, qn)
            if resolved is not None:
                hit = self._method(resolved[0], resolved[1], meth, _seen)
                if hit is not None:
                    return hit
        return None

    def resolve_call(self, ctx: ModuleContext, call: ast.Call
                     ) -> Optional[Tuple[_FuncInfo, bool]]:
        """(function info, bound?) for a call, or None if unresolvable.
        ``bound`` means the receiver supplies ``self`` (``self.m(...)``)."""
        f = call.func
        if isinstance(f, ast.Name):
            info = self._lookup_func(ctx, f.id)
            return (info, False) if info is not None else None
        if isinstance(f, ast.Attribute):
            qn = qualname(f)
            if not qn:
                return None
            head, _, rest = qn.partition(".")
            if head == "self" and rest and "." not in rest:
                cls = ctx.enclosing_class(call)
                if cls is not None:
                    info = self._method(ctx, cls, rest)
                    if info is not None:
                        return (info, True)
                return None
            info = self._lookup_func(ctx, qn)
            return (info, False) if info is not None else None
        return None

    @staticmethod
    def map_args(call: ast.Call, info: _FuncInfo,
                 bound: bool) -> Dict[int, ast.expr]:
        """callee param index -> caller argument expression."""
        args = info.node.args
        params = [a.arg for a in args.posonlyargs + args.args]
        offset = 1 if (bound and params and params[0] in ("self", "cls")) \
            else 0
        out: Dict[int, ast.expr] = {}
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                break
            pi = i + offset
            if pi < len(params):
                out[pi] = arg
        for kw in call.keywords:
            if kw.arg and kw.arg in params:
                out[params.index(kw.arg)] = kw.value
        return out

    # -- dataflow ------------------------------------------------------------

    def is_device_call(self, ctx: ModuleContext, call: ast.Call,
                       sums: Optional[Dict[str, FunctionSummary]] = None
                       ) -> bool:
        """Call that produces a device value: a jit-wrapped name, or a
        resolved callee whose summary says returns_device."""
        sums = sums if sums is not None else self.summaries
        f = call.func
        jitted = ctx.jitted_names
        if isinstance(f, ast.Name) and f.id in jitted:
            return True
        if isinstance(f, ast.Attribute) and f.attr in jitted:
            return True
        target = self.resolve_call(ctx, call)
        if target is None:
            return False
        callee = sums.get(target[0].fq)
        return bool(callee and callee.returns_device)

    def _flow(self, ctx: ModuleContext, fn: ast.FunctionDef,
              sums: Dict[str, FunctionSummary]
              ) -> Tuple[Dict[str, int], Set[str]]:
        """(param provenance, device-valued names) inside ``fn``.

        Provenance maps a local name to the parameter index it aliases
        (through plain assignments). Device names are results of jitted /
        returns_device calls, propagated through assignments; two passes so
        taint introduced late in a loop body reaches earlier statements on
        the next iteration. Assigning a sync result clears the taint (the
        copy lives on host) — mirrors ``rules_host._device_taint``.
        """
        args = fn.args
        params = [a.arg for a in args.posonlyargs + args.args]
        prov: Dict[str, int] = {p: i for i, p in enumerate(params)}
        device: Set[str] = set()
        for _ in range(2):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Assign):
                    continue
                val = node.value
                src_idx = prov.get(val.id) if isinstance(val, ast.Name) \
                    else None
                is_sync = any(isinstance(sub, ast.Call)
                              and _sync_call(sub, ctx) is not None
                              for sub in ast.walk(val))
                is_dev = not is_sync and any(
                    (isinstance(sub, ast.Call)
                     and self.is_device_call(ctx, sub, sums))
                    or (isinstance(sub, ast.Name)
                        and isinstance(sub.ctx, ast.Load)
                        and sub.id in device)
                    for sub in ast.walk(val))
                for tgt in node.targets:
                    elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                        else [tgt]
                    for t in elts:
                        if not isinstance(t, ast.Name):
                            continue
                        if src_idx is not None:
                            prov[t.id] = src_idx
                        else:
                            prov.pop(t.id, None)
                        (device.add if is_dev else device.discard)(t.id)
        return prov, device

    def device_names(self, ctx: ModuleContext,
                     fn: ast.FunctionDef) -> Set[str]:
        """Names in ``fn`` holding device values (final summaries)."""
        return self._flow(ctx, fn, self.summaries)[1]

    # -- summaries -----------------------------------------------------------

    def _fixpoint(self) -> Dict[str, FunctionSummary]:
        sums: Dict[str, FunctionSummary] = {}
        order = sorted(self._infos,
                       key=lambda i: (i.ctx.path, i.node.lineno))
        for info in order:
            args = info.node.args
            sums[info.fq] = FunctionSummary(
                params=tuple(a.arg for a in args.posonlyargs + args.args))
        for _ in range(_MAX_ROUNDS):
            changed = False
            for info in order:
                new = self._summarize(info, sums)
                if new.facts() != sums[info.fq].facts():
                    changed = True
                sums[info.fq] = new
            if not changed:
                break
        return sums

    def _resolved(self, ctx, call, sums):
        target = self.resolve_call(ctx, call)
        if target is None:
            return None, None, False
        info, bound = target
        return sums.get(info.fq), info, bound

    def _summarize(self, info: _FuncInfo,
                   sums: Dict[str, FunctionSummary]) -> FunctionSummary:
        ctx, fn = info.ctx, info.node
        prov, device = self._flow(ctx, fn, sums)
        args = fn.args
        s = FunctionSummary(
            params=tuple(a.arg for a in args.posonlyargs + args.args))
        short = Path(ctx.path).name

        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                sync = _sync_call(node, ctx)
                if sync is not None:
                    kind, expr = sync
                    idx = prov.get(root_name(expr))
                    if idx is not None:
                        s.syncs_params.add(idx)
                        s.sync_sites.setdefault(
                            idx, f"{kind} at {short}:{node.lineno}")
                    continue
                callee, cinfo, bound = self._resolved(ctx, node, sums)
                if callee is not None:
                    for pi, arg in self.map_args(node, cinfo, bound).items():
                        if pi not in callee.syncs_params:
                            continue
                        idx = prov.get(root_name(arg))
                        if idx is not None:
                            s.syncs_params.add(idx)
                            s.sync_sites.setdefault(
                                idx, callee.sync_sites.get(
                                    pi, f"via {cinfo.fq}()"))
            elif isinstance(node, ast.Return) and node.value is not None:
                v = node.value
                if isinstance(v, ast.Call) and _sync_call(v, ctx) is not None:
                    continue        # `return int(x)` comes back on host
                if any((isinstance(sub, ast.Call)
                        and self.is_device_call(ctx, sub, sums))
                       or (isinstance(sub, ast.Name) and sub.id in device)
                       for sub in ast.walk(v)):
                    s.returns_device = True

        self._consumes(ctx, fn, prov, sums, s)
        return s

    def _consumes(self, ctx, fn, prov, sums, s: FunctionSummary):
        """Ownership: a handle param that is stored, returned, entered,
        freed/ended, or forwarded to a consuming (or unresolvable —
        conservative) callee counts as consumed."""
        for node in ast.walk(fn):
            if isinstance(node, (ast.Return, ast.Yield, ast.YieldFrom)):
                val = getattr(node, "value", None)
                if val is None:
                    continue
                for sub in ast.walk(val):
                    if isinstance(sub, ast.Name) and sub.id in prov:
                        s.consumes_params.add(prov[sub.id])
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    r = root_name(item.context_expr)
                    if r in prov:
                        s.consumes_params.add(prov[r])
            elif isinstance(node, ast.Assign):
                if any(isinstance(t, (ast.Attribute, ast.Subscript))
                       for t in node.targets):
                    for sub in ast.walk(node.value):
                        if isinstance(sub, ast.Name) and sub.id in prov:
                            s.consumes_params.add(prov[sub.id])
            elif isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) \
                        and isinstance(f.value, ast.Name) \
                        and f.value.id in prov \
                        and f.attr in CONSUME_METHODS:
                    s.consumes_params.add(prov[f.value.id])
                callee, cinfo, bound = self._resolved(ctx, node, sums)
                handle_args = [a for a in list(node.args)
                               + [kw.value for kw in node.keywords]
                               if isinstance(a, ast.Name) and a.id in prov]
                if callee is None:
                    for a in handle_args:
                        s.consumes_params.add(prov[a.id])
                else:
                    for pi, arg in self.map_args(node, cinfo, bound).items():
                        if isinstance(arg, ast.Name) and arg.id in prov \
                                and pi in callee.consumes_params:
                            s.consumes_params.add(prov[arg.id])


def analyze_project(paths: Iterable[str],
                    select: Optional[Iterable[str]] = None,
                    ignore: Optional[Iterable[str]] = None) -> List[Finding]:
    """Whole-program lint: one :class:`ProjectIndex` over every path, then
    every rule per module with cross-module resolution available."""
    rules = select_rules(select, ignore)
    index, findings = ProjectIndex.from_paths(paths)
    for ctx in index.contexts:
        findings.extend(check_module(ctx, rules))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings
