"""INA228-probe model (paper Sec. 4.2).

A probe sits between the supply and the node, samples V/I at 4000 SPS, and
reports 4-sample averages (1000 SPS) with milliwatt resolution. The paper
trades the INA228's max 10000 SPS down to 4000 SPS for resolution; we model
exactly the reported configuration: each emitted sample carries the averaged
voltage, current, power, and the number of raw measurements averaged.

The probe is *driven* by a power model (``power_fn(t) -> W``): in deployment
that is the physical node; here it is the simulated node power trace (DVFS
model x utilization), which lets every energy experiment in the paper run
bit-faithfully on this cluster-less container.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional

import numpy as np

RAW_SPS = 4000          # INA228 configured rate (paper: reduced from 10000)
AVG_N = 4               # samples averaged per report
REPORT_SPS = RAW_SPS // AVG_N   # 1000 SPS
MILLIWATT = 1e-3        # reported resolution
MAX_PD_WATTS = 240.0    # USB PD 3.1 probe limit (paper Sec. 4.2)


@dataclasses.dataclass(frozen=True)
class Sample:
    """One averaged report (paper: V, I, P + averaging count)."""

    t: float            # seconds since stream start
    volts: float
    amps: float
    watts: float
    n_avg: int
    tags: tuple = ()    # GPIO tags active when the sample was taken


@dataclasses.dataclass
class ProbeConfig:
    probe_id: int = 0
    volts_nominal: float = 20.0      # USB-PD rail
    noise_w: float = 0.005           # measurement noise (W, std)
    max_watts: float = MAX_PD_WATTS
    seed: int = 0


class Probe:
    """Streams averaged samples from a power function."""

    def __init__(self, power_fn: Callable[[float], float],
                 cfg: Optional[ProbeConfig] = None):
        self.power_fn = power_fn
        self.cfg = cfg or ProbeConfig()
        self._rng = np.random.default_rng(self.cfg.seed + self.cfg.probe_id)

    def read(self, t0: float, duration: float) -> List[Sample]:
        """Samples in [t0, t0+duration): ``REPORT_SPS`` per second."""
        n_reports = int(round(duration * REPORT_SPS))
        out = []
        cfg = self.cfg
        for i in range(n_reports):
            t_rep = t0 + (i + 1) / REPORT_SPS
            raw_w = []
            for j in range(AVG_N):
                t_raw = t0 + (i * AVG_N + j + 1) / RAW_SPS
                w = float(np.clip(self.power_fn(t_raw), 0.0, cfg.max_watts))
                w += float(self._rng.normal(0.0, cfg.noise_w))
                raw_w.append(max(w, 0.0))
            watts = sum(raw_w) / AVG_N
            # milliwatt quantization (paper: mW-level resolution)
            watts = round(watts / MILLIWATT) * MILLIWATT
            volts = cfg.volts_nominal
            amps = watts / volts if volts else 0.0
            out.append(Sample(t_rep, volts, round(amps, 6), watts, AVG_N))
        return out


def read_vectorized(power_fn, t0: float, duration: float,
                    cfg: Optional[ProbeConfig] = None) -> np.ndarray:
    """Vectorized variant for long traces: returns [n, 2] (t, watts)."""
    cfg = cfg or ProbeConfig()
    n_raw = int(round(duration * RAW_SPS))
    t = t0 + (np.arange(n_raw) + 1) / RAW_SPS
    w = np.clip(np.vectorize(power_fn)(t), 0.0, cfg.max_watts)
    rng = np.random.default_rng(cfg.seed + cfg.probe_id)
    w = np.maximum(w + rng.normal(0.0, cfg.noise_w, n_raw), 0.0)
    w = w[: (n_raw // AVG_N) * AVG_N].reshape(-1, AVG_N).mean(axis=1)
    w = np.round(w / MILLIWATT) * MILLIWATT
    t_rep = t0 + (np.arange(w.shape[0]) + 1) / REPORT_SPS
    return np.stack([t_rep, w], axis=1)
