"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across
shape/dtype sweeps, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.stream import ops as stream_ops, ref as stream_ref
from repro.kernels.stream import stream as stream_k
from repro.kernels.dpa_matmul import ops as dpa_ops, ref as dpa_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref

I = dict(interpret=True)


# ---------------------------------------------------------------------------
# stream


@pytest.mark.parametrize("rows,cols", [(64, 128), (256, 128), (128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_suite(rows, cols, dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(rows, cols)), dtype)
    b = jnp.asarray(rng.normal(size=(rows, cols)), dtype)
    x = 1.7
    np.testing.assert_allclose(stream_k.stream_copy(a, **I), stream_ref.copy(a))
    np.testing.assert_allclose(
        np.asarray(stream_k.stream_scale(a, x, **I), np.float32),
        np.asarray(stream_ref.scale(a, x), np.float32), rtol=1e-2)
    np.testing.assert_allclose(
        np.asarray(stream_k.stream_add(a, b, **I), np.float32),
        np.asarray(stream_ref.add(a, b), np.float32), rtol=1e-2)
    np.testing.assert_allclose(
        np.asarray(stream_k.stream_triad(a, b, x, **I), np.float32),
        np.asarray(stream_ref.triad(a, b, x), np.float32),
        rtol=2e-2, atol=1e-2)


def test_stream_write_read():
    out = stream_k.stream_write((64, 128), 3.5, **I)
    np.testing.assert_allclose(out, stream_ref.write((64, 128), 3.5))
    a = jnp.asarray(np.random.default_rng(1).normal(size=(64, 128)), jnp.float32)
    np.testing.assert_allclose(stream_k.stream_read(a, block_rows=16, **I),
                               stream_ref.read(a, block_rows=16), rtol=1e-5)


# ---------------------------------------------------------------------------
# dpa_matmul


@pytest.mark.parametrize("variant,tol", [("fma_f32", 1e-5), ("dpa2", 2e-2),
                                         ("dpa4", 0)])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 128, 128, 128, 128),
    (256, 512, 128, 128, 128, 256),
    (64, 128, 64, 64, 64, 128),
])
def test_dpa_matmul(variant, tol, m, k, n, bm, bn, bk):
    rng = np.random.default_rng(2)
    if variant == "dpa4":
        a = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    else:
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = dpa_ops.matmul(a, b, variant=variant, interpret=True)
    want = dpa_ref.matmul(a, b, variant)
    if variant == "dpa4":
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol * k ** 0.5 + 1e-6, atol=tol * 4)


def test_quantized_linear_close_to_fp():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(128, 64)) / np.sqrt(128), jnp.float32)
    got = dpa_ops.quantized_linear(x, w, interpret=True)
    want = x @ w
    err = np.abs(np.asarray(got - want)) / (np.abs(np.asarray(want)) + 1e-2)
    assert np.median(err) < 0.05


@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([64, 128]), k=st.sampled_from([128, 256]),
       n=st.sampled_from([64, 128]), seed=st.integers(0, 2**16))
def test_dpa4_exact_int_property(m, k, n, seed):
    """int8 DPA accumulation is EXACT (no rounding) — paper's DPA4 claim."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    got = dpa_ops.matmul(a, b, variant="dpa4", interpret=True)
    want = np.asarray(a, np.int32) @ np.asarray(b, np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("b,h,s,t,d", [
    (1, 2, 128, 128, 64),
    (2, 1, 256, 256, 128),
    (1, 2, 128, 256, 64),   # cross-length (q shorter than kv)
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, h, s, t, d, causal, dtype):
    if causal and s != t:
        pytest.skip("causal with s<t needs offset semantics")
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, h, t, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, h, t, d)), dtype)
    got = fa_ops.attention(q, k, v, causal=causal, interpret=True)
    want = fa_ref.attention(q, k, v, causal=causal)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    got = fa_ops.attention(q, k, v, causal=True, window=window,
                           block_q=64, block_kv=64, interpret=True)
    want = fa_ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16),
       bq=st.sampled_from([32, 64, 128]),
       bkv=st.sampled_from([32, 64, 128]))
def test_flash_block_shape_invariance(seed, bq, bkv):
    """Property: output independent of the VMEM block decomposition."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 1, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, 128, 64)), jnp.float32)
    got = fa_ops.attention(q, k, v, causal=True, block_q=bq, block_kv=bkv,
                           interpret=True)
    want = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
