"""Paper Fig. 8 (Sec. 5.5): kernel launch latency.

On DALEK this is the OpenCL enqueue-to-start latency (5-90 us across GPUs).
The JAX/TPU analogues measured here: jitted-callable dispatch overhead
(cached executable), pallas_call dispatch, and trace+compile cost (the
"first-launch" latency users actually hit).
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn


def run():
    x = jnp.zeros((8, 8), jnp.float32)

    # the bare jitted callable IS the measurement subject — a counting
    # wrapper would sit inside the timed dispatch path
    @jax.jit  # dalek: allow[bare-jit] dispatch-latency measurement subject
    def tiny(v):
        return v + 1.0

    t = time_fn(tiny, x, warmup=3, iters=20)
    emit("launch/jit_dispatch", t, "cached-executable")

    from repro.kernels.stream import stream as sk
    t = time_fn(lambda: sk.stream_copy(x, block_rows=8, interpret=True),
                warmup=2, iters=5)
    emit("launch/pallas_interpret", t, "interpret-mode")

    def fresh():
        # first-launch latency measures raw jax.jit trace+compile;
        # wrapping would add non-XLA time to the figure
        @jax.jit  # dalek: allow[bare-jit] trace+compile measurement subject
        def f(v):
            return v * 2.0
        return f(x)

    t0 = time.perf_counter()
    jax.block_until_ready(fresh())
    emit("launch/trace_compile", time.perf_counter() - t0, "first-launch")


if __name__ == "__main__":
    run()
