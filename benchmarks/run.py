"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only <substr>]
"""
import argparse
import sys
import traceback

MODULES = [
    ("bandwidth (Fig. 4/6)", "benchmarks.bench_bandwidth"),
    ("peak compute (Fig. 5/7)", "benchmarks.bench_peak"),
    ("launch latency (Fig. 8)", "benchmarks.bench_launch_latency"),
    ("checkpoint/SSD IO (Fig. 9)", "benchmarks.bench_checkpoint_io"),
    ("energy platform (Sec. 4)", "benchmarks.bench_energy_platform"),
    ("elastic power (Sec. 3.4)", "benchmarks.bench_elastic"),
    ("hetero scheduling (Sec. 6.1)", "benchmarks.bench_scheduler"),
    ("roofline (dry-run)", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for label, mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        print(f"# {label}", file=sys.stderr)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:  # noqa
            failures += 1
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
