"""Gradient compression for the slow inter-pod axis (DALEK's 2.5 GbE lesson).

The pod axis carries pure data parallelism; its gradient all-reduce crosses
the slow DCN link. We compress that reduction: int8 block-quantized
all-reduce with error feedback (residuals carried between steps keep the
optimizer unbiased in expectation and empirically lossless after warmup).

Implemented with shard_map over the ``pod`` axis so the quantize ->
all-reduce(int-sum) -> dequantize pipeline is explicit in the collective
schedule (visible to the roofline walker as an ~4x smaller DCN transfer
vs f32).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


BLOCK = 256


def _quantize_blockwise(x, block=BLOCK):
    """f32 [N] -> (int8 [N], scale f32 [N/block]). N padded to block."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad))
    xb = xp.reshape(-1, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def compressed_psum_pod(grad_flat, *, axis_name="pod"):
    """int8 all-reduce over ``axis_name``; returns f32 mean gradient.

    int8 values are summed in int32 (exact for <=2^24/127 pods), then
    dequantized with the max scale — one extra tiny scale all-reduce.
    """
    n = grad_flat.shape[0]
    q, scale = _quantize_blockwise(grad_flat)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so integer sums are coherent
    qs = jnp.clip(jnp.round(
        q.astype(jnp.float32) * scale / scale_max), -127, 127).astype(jnp.int8)
    summed = jax.lax.psum(qs.astype(jnp.int32), axis_name)
    n_pods = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return _dequantize(summed, scale_max, n) / n_pods.astype(jnp.float32)


def compress_grads_over_pod(grads, mesh, error_state=None):
    """Apply error-feedback int8 compression to the pod-axis reduction.

    grads: pytree of f32 arrays whose pod-axis reduction has NOT yet
    happened (use inside shard_map, or on per-pod partial grads).
    error_state: matching pytree of residuals (or None -> zeros).
    Returns (reduced_grads, new_error_state).
    """
    if error_state is None:
        error_state = jax.tree.map(jnp.zeros_like, grads)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    sizes = [g.size for g in flat_g]
    shapes = [g.shape for g in flat_g]
    vec = jnp.concatenate([g.reshape(-1) + e.reshape(-1)
                           for g, e in zip(flat_g, flat_e)])

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=P(None), out_specs=P(None))
    def reduce_fn(v):
        return compressed_psum_pod(v[0] if v.ndim > 1 else v)

    # approximate local quantization for the error feedback bookkeeping
    q, scale = _quantize_blockwise(vec)
    approx = _dequantize(q, scale, vec.shape[0])
    new_err_vec = vec - approx

    reduced = reduce_fn(vec)
    out_g, out_e, off = [], [], 0
    for shape, size in zip(shapes, sizes):
        out_g.append(reduced[off:off + size].reshape(shape))
        out_e.append(new_err_vec[off:off + size].reshape(shape))
        off += size
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))


def compression_ratio(n_params: int) -> float:
    """Bytes on the wire vs f32 all-reduce (scales included)."""
    f32_bytes = 4 * n_params
    int8_bytes = n_params + 4 * (n_params // BLOCK + 1)
    return f32_bytes / int8_bytes
