"""Paper Sec. 3.4 (Tab. 2): elastic power management over a simulated day.

A bursty job arrival pattern on the DALEK cluster; derived column = energy
with suspend/resume vs always-idle baseline, and the idle-cluster wattage
(paper claims ~50 W with nodes off).
"""
from benchmarks.common import emit, time_fn
from repro.cluster.manager import ClusterManager
from repro.cluster.topology import dalek_topology
from repro.core import hw


def _simulate():
    cm = ClusterManager(dalek_topology())
    arrivals = [(h * 3600.0, "az4-n4090", 2, 1800.0) for h in (1, 3, 9)]
    arrivals += [(2 * 3600.0, "az5-a890m", 4, 7200.0)]
    t = 0.0
    for at, part, n, dur in arrivals:
        cm.advance(at - t)
        cm.submit("user", part, n, dur)
        t = at
    cm.advance(24 * 3600.0 - t)
    return cm


def run():
    t = time_fn(_simulate, warmup=0, iters=1)
    cm = _simulate()
    e_elastic = cm.elastic.total_energy_j()
    # baseline: all nodes idle all day
    idle_w = sum(p.idle_w for p in hw.DALEK_PARTITIONS.values())
    e_idle = idle_w * 24 * 3600
    saved = 1 - (e_elastic / e_idle)
    emit("elastic/day_sim", t,
         f"saved={saved * 100:.0f}%;idle_cluster={hw.cluster_idle_w('off'):.0f}W")


if __name__ == "__main__":
    run()
