"""Paper Sec. 6.1 (HCW'25 use case): heterogeneous two-resource scheduling.

Task chains placed across p-core/e-core classes under time vs energy vs EDP
objectives; derived column compares against the best single-class baseline.
``--json PATH`` dumps the rows for the CI perf-trajectory artifact.

    PYTHONPATH=src python -m benchmarks.bench_scheduler [--json PATH]
"""
import argparse

from benchmarks.common import BenchRows, time_fn
from repro.core import hw
from repro.core.scheduler import HeterogeneousScheduler, ResourceClass, Task


def run(json_path=None):
    rows = BenchRows()
    classes = [
        ResourceClass("p-cores", hw.RYZEN_7945HX, 4, efficiency=0.8),
        ResourceClass("e-cores", hw.RYZEN_AI_HX370, 8, efficiency=0.7),
    ]
    tasks = []
    for c in range(4):  # four chains of six tasks
        for i in range(6):
            deps = (f"c{c}t{i-1}",) if i else ()
            tasks.append(Task(f"c{c}t{i}", flops=2e12, deps=deps))

    for obj in ("time", "energy", "edp"):
        sched = HeterogeneousScheduler(classes, obj)
        t = time_fn(lambda: sched.schedule(tasks), warmup=0, iters=3)
        _, stats = sched.schedule(tasks)
        base = HeterogeneousScheduler(classes[:1], "time")
        _, bstats = base.schedule(tasks)
        speedup = bstats["makespan_s"] / stats["makespan_s"]
        rows.record(f"sched/{obj}", t,
                    f"makespan={stats['makespan_s']:.1f}s;"
                    f"energy={stats['energy_j']:.0f}J;"
                    f"vs_pcore_only={speedup:.2f}x")
    rows.dump(json_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="dump rows as JSON (CI perf-trajectory artifact)")
    run(ap.parse_args().json)
