"""DLK007 unclosed-span.

A :class:`repro.obs.spans.Span` that is opened but never ended is invisible
in the exported timeline (``Tracer.spans()`` only returns finished spans)
and silently breaks the energy partition: the sample window its compute
landed in ends up attributed to no span at all. The discipline is lexical:

* ``tracer.span(...)`` is the lexical form — its result must be entered
  with ``with`` (directly, or via a named handle), never discarded;
* ``h = tracer.begin(...)`` is the non-lexical form — some path must call
  ``h.end(...)`` (or enter ``h`` with ``with``).

The rule is receiver-shaped (anything whose dotted name contains
"tracer") and deliberately conservative: name handles must close inside
their enclosing function, attribute handles (``self._sp = ...``) anywhere
in the module (another method may own the close); handles stored
into containers (subscript targets), returned, or passed to calls transfer
ownership and are skipped.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import (Finding, ModuleContext, Rule, qualname,
                                 register)


def _tracer_receiver(func) -> Optional[str]:
    """Receiver text for ``<tracer>.span``/``<tracer>.begin`` calls on
    something tracer-shaped (``self.tracer``, ``tracer``, ``req_tracer``)."""
    if not isinstance(func, ast.Attribute):
        return None
    recv = qualname(func.value)
    if not recv or recv == "self":
        return None
    if "tracer" in recv.lower():
        return recv
    return None


def _in_with_item(ctx: ModuleContext, call: ast.Call) -> bool:
    """Is the call (a descendant of) some ``with`` item's context expr?"""
    for anc in ctx.ancestors(call):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                if item.context_expr is call or any(
                        n is call for n in ast.walk(item.context_expr)):
                    return True
    return False


def _name_entered_or_ended(tree, name: str, after_line: int) -> bool:
    """Does ``name`` later flow into a ``with`` or a ``.end(`` call?"""
    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                for sub in ast.walk(item.context_expr):
                    if isinstance(sub, ast.Name) and sub.id == name:
                        return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "end":
            base = node.func.value
            if isinstance(base, ast.Name) and base.id == name:
                return True
    return False


def _attr_ended(tree, attr: str) -> bool:
    """``<anything>.<attr>.end(`` anywhere in the module (handle stored on
    an object; any method may close it)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "end":
            base = node.func.value
            if isinstance(base, ast.Attribute) and base.attr == attr:
                return True
    return False


@register
class UnclosedSpan(Rule):
    """Tracer span opened outside ``with`` and never ended on any path."""

    code = "DLK007"
    name = "unclosed-span"
    skip_tests = True      # tests open dangling spans to probe the tracer

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("span", "begin")):
                continue
            recv = _tracer_receiver(node.func)
            if recv is None:
                continue
            if _in_with_item(ctx, node):
                continue                 # `with tracer.span(...)`: closed
            parent = ctx.parent(node)

            # result discarded outright: the span can never be ended
            if isinstance(parent, ast.Expr):
                yield ctx.finding(
                    self, node,
                    f"result of {recv}.{node.func.attr}() discarded — the "
                    "span is never ended and will not appear in the "
                    "exported timeline")
                continue

            # walk up through value wrappers (ternary, boolop) to the
            # binding statement; non-Assign consumers (return / call arg /
            # comprehension) transfer ownership and are skipped
            stmt = parent
            while stmt is not None and not isinstance(stmt, ast.stmt):
                stmt = ctx.parent(stmt)
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                continue
            tgt = stmt.targets[0]
            fn = ctx.enclosing_function(node)
            scope = fn if fn is not None else ctx.tree
            if isinstance(tgt, ast.Name):
                if not _name_entered_or_ended(scope, tgt.id, stmt.lineno):
                    yield ctx.finding(
                        self, node,
                        f"'{tgt.id}' = {recv}.{node.func.attr}() is never "
                        "entered with 'with' nor ended with "
                        f"'{tgt.id}.end()' — unclosed span")
            elif isinstance(tgt, ast.Attribute):
                if not _attr_ended(ctx.tree, tgt.attr):
                    yield ctx.finding(
                        self, node,
                        f"'{qualname(tgt)}' = {recv}.{node.func.attr}() has "
                        f"no matching '.{tgt.attr}.end()' in this module — "
                        "unclosed span")
            # Subscript / Tuple targets: stored into a collection, ownership
            # transferred (e.g. an engine's req_id -> span map)
