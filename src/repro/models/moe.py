"""Fine-grained Mixture-of-Experts (deepseek-moe / moonlight style).

Shared experts (always-on, fused into one dense SwiGLU) + routed experts with
top-k routing, fixed capacity and token dropping.

Dispatch is *group-local*: tokens are split into ``groups`` row-blocks that
GSPMD maps onto the ``("pod","data")`` axes, so the argsort-based routing is
device-local and only the expert einsum (E sharded over ``model``) moves data
— the EP all-to-all. This mirrors DALEK's lesson that the slow network makes
communication structure a first-class design concern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import ParamBuilder
from repro.parallel.sharding import Sharder


def moe_init(pb: ParamBuilder, cfg: ModelConfig, L=None):
    pre = (L,) if L is not None else ()
    pax = ("layers",) if L is not None else ()
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    pb.dense("router", pre + (d, e), pax + ("embed", "experts"), fan_in=d)
    pb.dense("w_gate", pre + (e, d, f), pax + ("experts", "embed", "expert_mlp"), fan_in=d)
    pb.dense("w_up", pre + (e, d, f), pax + ("experts", "embed", "expert_mlp"), fan_in=d)
    pb.dense("w_down", pre + (e, f, d), pax + ("experts", "expert_mlp", "embed"), fan_in=f)
    if cfg.num_shared_experts:
        sb = pb.child("shared")
        common.mlp_init(sb, d, cfg.num_shared_experts * f, L)


def _route_group(xg, router_logits, cfg: ModelConfig, capacity: int):
    """Group-local routing. xg: [T, D]; router_logits: [T, E].

    Returns (dispatch buffer [E, C, D], combine indices, weights, keep mask,
    aux loss terms).
    """
    t, d = xg.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, ids = lax.top_k(probs, k)                       # [T, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    ids_flat = ids.reshape(-1)                               # [T*k]
    order = jnp.argsort(ids_flat, stable=True)
    sorted_eid = ids_flat[order]
    start = jnp.searchsorted(sorted_eid, jnp.arange(e), side="left")
    rank = jnp.arange(t * k) - start[sorted_eid]             # within-expert rank
    keep = rank < capacity
    slot = jnp.where(keep, sorted_eid * capacity + rank, e * capacity)
    tok = order // k                                         # source token

    buf = jnp.zeros((e * capacity + 1, d), xg.dtype)
    buf = buf.at[slot].set(xg[tok], mode="drop")
    dispatch = buf[:-1].reshape(e, capacity, d)

    # aux (load-balance) loss terms, Switch-style
    frac = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_prob)
    return dispatch, (slot, tok, order), weights, keep, aux


def _combine_group(expert_out, routing, weights, keep, t, k):
    """expert_out: [E, C, D] -> y [T, D]."""
    e, c, d = expert_out.shape
    slot, tok, order = routing
    flat = jnp.concatenate(
        [expert_out.reshape(e * c, d), jnp.zeros((1, d), expert_out.dtype)])
    contrib = flat[slot]                                     # [T*k, D] (sorted order)
    w_flat = weights.reshape(-1)[order]
    contrib = contrib * jnp.where(keep, w_flat, 0.0).astype(contrib.dtype)[:, None]
    y = jnp.zeros((t, d), expert_out.dtype).at[tok].add(contrib)
    return y


def moe_apply_shard_map(x, p, cfg: ModelConfig, shd: Sharder):
    """Expert parallelism with explicit all-to-all (shard_map).

    GSPMD lowers the sort-based dispatch's scatter into replicated-buffer
    all-reduces (~10x the necessary traffic — measured in §Perf). This path
    keeps routing device-local and moves ONLY the dispatch/return buffers
    over the ``model`` axis with jax.lax.all_to_all:

        tokens [B(data),S,D] -> local top-k routing -> [E, C_l, D] buffer
        -> all_to_all(model) -> each device computes its E/TP experts on
        TP*C_l slots -> all_to_all back -> local weighted combine.
    """
    from jax.sharding import PartitionSpec as P
    import functools

    from repro.parallel.sharding import spec_for

    mesh = shd.mesh
    e, k = cfg.num_experts, cfg.experts_per_token
    b, s, d = x.shape
    tp = mesh.shape["model"]
    assert e % tp == 0
    e_local = e // tp
    # how is the batch actually sharded? (2d: (pod,data); zero-3: all axes)
    bspec = spec_for(mesh, ("batch",), (b,), shd.rules)
    ax0 = bspec[0] if len(bspec) else None
    batch_axes = (() if ax0 is None
                  else (ax0,) if isinstance(ax0, str) else tuple(ax0))
    dp_axes = tuple(a for a in batch_axes if a != "model")
    tokens_cover_model = "model" in batch_axes
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    t_local = (b // dp) * s

    if tokens_cover_model:
        t_rank = t_local            # tokens already sharded over "model"
    else:
        assert t_local % tp == 0
        t_rank = t_local // tp      # each TP rank routes its token slice
    capacity = max(int(np.ceil(cfg.capacity_factor * t_rank * k / e)), 1)

    def local_fn(xl, router, wg, wu, wd):
        # xl: [B_l, S, D]; router: [D, E]; wg/wu/wd: [E_l, D, F] (this
        # device's experts). When tokens are replicated over "model", each
        # rank routes only its 1/TP slice — no duplicated routing work.
        bl = xl.shape[0]
        xf = xl.reshape(bl * s, d)
        if tokens_cover_model:
            xr = xf
        else:
            rank = jax.lax.axis_index("model")
            xr = jax.lax.dynamic_slice_in_dim(xf, rank * t_rank, t_rank, 0)
        logits = jnp.einsum("td,de->te", xr, router.astype(xr.dtype))
        dispatch, routing, weights, keep, aux = _route_group(
            xr, logits, cfg, capacity)                 # [E, C, D] local
        buf = dispatch.reshape(tp, e_local, capacity, d)
        # exchange: device m receives every peer's slice for ITS experts
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                  tiled=False)      # [tp(src), E_l, C, D]
        recv = recv.swapaxes(0, 1).reshape(e_local, tp * capacity, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg.astype(recv.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", recv, wu.astype(recv.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, wd.astype(h.dtype))
        out = out.reshape(e_local, tp, capacity, d).swapaxes(0, 1)
        back = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0,
                                  tiled=False)          # [tp, e_local, C, D]
        back = back.reshape(e, capacity, d)
        yr = _combine_group(back, routing, weights, keep, t_rank, k)
        if tokens_cover_model:
            y = yr
        else:
            # reassemble the full local token set from all TP ranks
            y = jax.lax.all_gather(yr, "model", axis=0, tiled=True)
        aux = jax.lax.pmean(aux, "model")
        for a in dp_axes:
            aux = jax.lax.pmean(aux, a)
        return y.reshape(bl, s, d), aux

    batch_spec = P(batch_axes if batch_axes else None)
    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(batch_spec, P(), P("model"), P("model"), P("model")),
        out_specs=(batch_spec, P()),
        check_vma=False)
    y, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if cfg.num_shared_experts:
        y = y + common.mlp(x, p["shared"], shd)
    return y, jnp.mean(aux)


def moe_apply(x, p, cfg: ModelConfig, shd: Sharder, groups: int = 0,
              impl: str = "gspmd"):
    if impl == "shard_map" and shd.mesh is not None and not shd.mesh.empty:
        return moe_apply_shard_map(x, p, cfg, shd)
    return _moe_apply_gspmd(x, p, cfg, shd, groups)


def _moe_apply_gspmd(x, p, cfg: ModelConfig, shd: Sharder, groups: int = 0):
    """x: [B, S, D] -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k, f = cfg.num_experts, cfg.experts_per_token, cfg.moe_d_ff
    t_total = b * s
    if groups <= 0:
        # one group per batch shard: routing stays device-local and only the
        # expert einsum communicates
        n_shards = 32
        if shd.mesh is not None and not shd.mesh.empty:
            from repro.parallel.sharding import spec_for
            spec = spec_for(shd.mesh, ("batch",), (b,), shd.rules)
            ax = spec[0] if len(spec) else None
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                n_shards = 1
                for a in axes:
                    n_shards *= shd.mesh.shape[a]
        groups = int(np.gcd(b, n_shards))
    tg = t_total // groups
    capacity = max(int(np.ceil(cfg.capacity_factor * tg * k / e)), 1)

    xf = x.reshape(groups, tg, d)
    xf = shd(xf, "batch", None, "act_embed")
    logits = jnp.einsum("gtd,de->gte", xf, p["router"].astype(x.dtype))

    dispatch, routing, weights, keep, aux = jax.vmap(
        lambda xg, lg: _route_group(xg, lg, cfg, capacity))(xf, logits)
    # dispatch: [G, E, C, D] — G on ("pod","data"), E on "model" => EP all-to-all
    dispatch = shd(dispatch, "batch", "act_experts", None, None)

    wg, wu, wd = (p["w_gate"].astype(x.dtype), p["w_up"].astype(x.dtype),
                  p["w_down"].astype(x.dtype))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", dispatch, wg))
    h = h * jnp.einsum("gecd,edf->gecf", dispatch, wu)
    h = shd(h, "batch", "act_experts", None, None)
    out = jnp.einsum("gecf,efd->gecd", h, wd)
    out = shd(out, "batch", "act_experts", None, None)

    y = jax.vmap(
        lambda eo, rt, w, kp: _combine_group(eo, rt, w, kp, tg, k)
    )(out, routing, weights, keep)
    y = y.reshape(b, s, d)
    y = shd(y, "batch", "seq", "act_embed")

    if cfg.num_shared_experts:
        y = y + common.mlp(x, p["shared"], shd)
    return y, jnp.mean(aux)
