"""Elastic cluster simulation (paper Sec. 3.4): a day of bursty jobs on the
DALEK topology with WoL resume + 10-min idle power-off, energy quotas
(Sec. 6.2) and login policy (Sec. 3.5).

    PYTHONPATH=src python examples/elastic_cluster.py
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

from repro.cluster.manager import ClusterManager
from repro.cluster.topology import dalek_topology
from repro.core import hw


def main():
    cm = ClusterManager(dalek_topology())
    cm.set_quota("grad_student", energy_j=5e7)    # ~14 kWh... generous
    print(f"idle cluster (nodes off): {hw.cluster_idle_w('off'):.0f} W "
          f"(paper claims ~50 W)")

    j1 = cm.submit("grad_student", "az4-n4090", 2, 3600.0)
    print(f"job {j1.job_id}: {j1.state} on {j1.nodes} "
          f"(boot delay {j1.start_t - cm.elastic.t:.0f}s <= 120s)")
    cm.advance(130.0)
    print(f"  t+130s: {cm.jobs[j1.job_id].state}; "
          f"login allowed: {cm.can_login('grad_student', j1.nodes[0])}; "
          f"stranger: {cm.can_login('stranger', j1.nodes[0])}")
    cm.advance(3600.0)
    j = cm.jobs[j1.job_id]
    print(f"  done: {j.state}, energy {j.energy_j/3.6e6:.2f} kWh; "
          f"quota used {cm.quota('grad_student').used_energy_j/3.6e6:.2f} kWh")

    cm.advance(700.0)   # > 10 min idle -> nodes power off
    states = cm.elastic.states()
    print(f"after idle timeout: {set(states[n] for n in j.nodes)}")
    day_j = cm.elastic.total_energy_j()
    # fair baseline: same job energy, but all 16 nodes sit idle when unused
    # instead of powering off
    idle_day = (sum(p.idle_w for p in hw.DALEK_PARTITIONS.values())
                * cm.elastic.t + j.energy_j)
    print(f"energy so far {day_j/3.6e6:.2f} kWh vs always-on baseline "
          f"{idle_day/3.6e6:.2f} kWh -> saved "
          f"{(1 - day_j/idle_day)*100:.0f}%")


if __name__ == "__main__":
    main()
