import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and record roofline inputs.

Run:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in results/dryrun/<mesh>/<arch>__<shape>.json and are consumed
by the roofline report (benchmarks/bench_roofline.py, EXPERIMENTS.md).
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES
from repro.core.tracing import TraceStats, counting_jit
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, build_model, token_batch_specs
from repro.perf import hlo_analysis, roofline
from repro.serve.step import abstract_cache, cache_specs
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig
from repro.train.step import (TrainState, batch_specs, make_train_step,
                              param_specs, state_specs, StepConfig)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# per-shape microbatching (keeps the per-device activation stash inside HBM;
# see EXPERIMENTS.md §Perf for the iteration that chose these)
N_MICRO = {"train_4k": 16}


def serve_rules(cfg, shape, mesh):
    """Cell-specific sharding-rule overrides for serving."""
    rules = {}
    model_size = mesh.shape.get("model", 1)
    if shape.global_batch == 1:
        # long-context decode, batch unshardable: sequence-shard the caches
        rules["batch"] = None
        rules["kv_seq"] = ("pod", "data") if "pod" in mesh.shape else ("data",)
    elif cfg.num_kv_heads % model_size != 0:
        # GQA/MQA: too few KV heads for TP -> shard cache sequence instead
        # (flash-decoding-style parallel KV)
        rules["kv_seq"] = "model"
    return rules


FSDP_RULES = {
    # pure ZeRO-3 layout: every param's embed dim + the batch sharded over
    # the WHOLE mesh; no tensor parallelism (no activation all-reduces)
    "embed": ("pod", "data", "model"),
    "vocab": None, "heads": None, "kv_heads": None, "mlp": None,
    "experts": None, "ssm_inner": None,
    "batch": ("pod", "data", "model"),
    "act_heads": None, "act_kv_heads": None, "act_experts": None,
    "act_vocab": None, "act_mlp": None,
}

EP_RULES = {
    # MoE hybrid: ZeRO-3 everywhere (batch + param embed dims over the whole
    # mesh) EXCEPT experts, which shard over ``model`` and run via shard_map
    # all-to-all EP — no TP activation all-reduces, no expert-weight gathers,
    # dense compute fully data-parallel
    "embed": ("pod", "data", "model"),
    "vocab": None, "heads": None, "kv_heads": None, "mlp": None,
    "experts": "model", "ssm_inner": None,
    "batch": ("pod", "data", "model"),
    "act_heads": None, "act_kv_heads": None, "act_experts": None,
    "act_vocab": None, "act_mlp": None,
}


def build_cell(arch: str, shape_name: str, mesh, variant=None):
    """Returns (jitted_fn, abstract_args, cfg, shape, static_info).

    variant (perf hillclimbing): {layout: "2d"|"fsdp", n_micro: int,
    cast_once: bool, barrier: bool}.
    """
    variant = variant or {}
    cfg = configs.get(arch).adapt_for_mesh(mesh.shape.get("model", 1))
    shape = SHAPES[shape_name]
    n_pods = mesh.shape.get("pod", 1)
    n_chips = mesh.devices.size

    if shape.kind == "train":
        layout = variant.get("layout", "2d")
        rules = None
        if layout == "fsdp":
            rules = dict(FSDP_RULES)
        elif layout == "ep":
            rules = dict(EP_RULES)
            variant = dict(variant, moe_shard_map=1, n_micro=1)
        if rules is not None:
            # drop axes absent from this mesh
            rules = {k: (tuple(a for a in v if a in mesh.shape)
                         if isinstance(v, tuple) else v)
                     for k, v in rules.items()}
            if layout == "fsdp":
                assert shape.global_batch % n_chips == 0, \
                    "fsdp layout needs batch divisible by chip count"
        model_kw = dict(shd_rules=rules, barrier=variant.get("barrier", False))
        if variant.get("scores_bf16") and cfg.family in ("dense", "moe", "vlm"):
            model_kw["scores_f32"] = False
        if variant.get("carry_barrier") and cfg.family in ("dense", "moe", "vlm"):
            model_kw["carry_barrier"] = True
        if variant.get("moe_shard_map") and cfg.is_moe:
            model_kw["moe_impl"] = "shard_map"
        model = build_model(cfg, mesh, **model_kw)
        params_sds, axes = abstract_params(model)
        opt_sds = opt_mod.abstract_opt_state(params_sds)
        state_sds = TrainState(params_sds, opt_sds)
        batch_sds = token_batch_specs(cfg, shape)
        dp_total = n_chips // mesh.shape.get("model", 1)
        default_micro = 1 if layout == "fsdp" else min(
            N_MICRO.get(shape_name, 8),
            max(shape.global_batch // (dp_total or 1), 1))
        n_micro = variant.get("n_micro", default_micro)
        step_cfg = StepConfig(num_microbatches=n_micro,
                              cast_params_once=variant.get("cast_once", False),
                              vocab_chunks=variant.get("vocab_chunks", 1))
        fn = make_train_step(model, OptConfig(), step_cfg)
        in_shardings = (
            jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                         state_specs(mesh, params_sds, axes, rules),
                         is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
            jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                         batch_specs(mesh, batch_sds, rules),
                         is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
        )
        jitted = counting_jit(fn, f"train:{arch}/{shape_name}", TraceStats(),
                              in_shardings=in_shardings, donate_argnums=(0,))
        args = (state_sds, batch_sds)
        info = {"kind": "train", "n_micro": n_micro, "layout": layout,
                "variant": {k: v for k, v in variant.items()}}
        return jitted, args, cfg, shape, info

    # serving cells: bf16 params
    scfg = cfg.replace(param_dtype="bfloat16")
    model = build_model(scfg, mesh)
    params_sds, axes = abstract_params(model)
    rules = serve_rules(scfg, shape, mesh)
    pspecs = param_specs(mesh, params_sds, axes, rules)
    psh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    max_seq = shape.seq_len + (
        scfg.stub_prefix_len if scfg.family == "vlm" else 0)
    cache_sds = abstract_cache(model, shape.global_batch, max_seq)
    cspecs = cache_specs(mesh, model, cache_sds, rules)
    csh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), cspecs,
                       is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    if shape.kind == "prefill":
        batch_sds = token_batch_specs(scfg, shape)
        bsh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                           batch_specs(mesh, batch_sds, rules),
                           is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        fn = lambda p, b, c: model.prefill(p, b, c)
        jitted = counting_jit(fn, f"prefill:{arch}/{shape_name}", TraceStats(),
                              in_shardings=(psh, bsh, csh),
                              donate_argnums=(2,))
        args = (params_sds, batch_sds, cache_sds)
        return jitted, args, scfg, shape, {"kind": "prefill", "rules": str(rules)}

    # decode: one new token against a cache of seq_len
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_spec = batch_specs(mesh, tok_sds, rules)
    tsh = jax.sharding.NamedSharding(mesh, tok_spec)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(p, tok, pos, c):
        return model.decode_step(p, tok, pos, c)

    jitted = counting_jit(fn, f"decode:{arch}/{shape_name}", TraceStats(),
                          in_shardings=(psh, tsh, None, csh),
                          donate_argnums=(3,))
    args = (params_sds, tok_sds, pos_sds, cache_sds)
    return jitted, args, scfg, shape, {"kind": "decode", "rules": str(rules)}


def run_cell(arch: str, shape_name: str, mesh_kind: str, force=False):
    out_dir = RESULTS / mesh_kind
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if "error" not in rec:
            print(f"[skip] {mesh_kind}/{arch}/{shape_name} (cached)")
            return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    pod_block = 256 if mesh_kind == "multi" else None
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_chips": n_chips}
    try:
        jitted, args, cfg, shape, info = build_cell(arch, shape_name, mesh)
        rec.update(info)
        with mesh:
            t_l = time.time()
            # counting_jit's AOT hook: the lower records one trace on the
            # cell's TraceStats, so dryrun executables are metered too
            lowered = jitted.lower(*args)
            rec["lower_s"] = time.time() - t_l
            t_c = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t_c
            print(compiled.memory_analysis())
            t_kv = shape.seq_len + (
                cfg.stub_prefix_len if cfg.family == "vlm" else 0)
            analysis = hlo_analysis.analyze(compiled, pod_block,
                                            fused_attn_shapes=(512, t_kv))
            ca = compiled.cost_analysis()
            print({k: v for k, v in (ca[0] if isinstance(ca, list) else ca).items()
                   if k in ("flops", "bytes accessed")})
        if shape.kind == "train":
            params_sds = args[0].params
        else:
            params_sds = args[0]
        n_total = roofline.count_params(params_sds)
        n_active = roofline.active_params(cfg, n_total)
        mf = roofline.model_flops(cfg, shape, n_active)
        rl = roofline.compute_roofline(analysis, n_chips, mf)
        rec.update(analysis=analysis, roofline=rl.to_dict(),
                   n_params=n_total, n_params_active=n_active,
                   jit_traces=jitted.stats.snapshot(),
                   wall_s=time.time() - t0)
        hbm_gb = (analysis["memory"]["argument_bytes"]
                  + analysis["memory"]["temp_bytes"]) / 2**30
        rec["hbm_per_device_gb"] = hbm_gb
        rec["hbm_adjusted_gb"] = hbm_gb - analysis.get(
            "f32_hoist_bytes", 0.0) / 2**30
        mem_k = (analysis["bytes_accessed"]
                 - analysis.get("attn_score_bytes", 0.0)) / roofline.HBM_BW
        rec["memory_s_with_kernel"] = mem_k
        t_k = max(rl.compute_s, mem_k, rl.collective_s)
        rec["roofline_frac_with_kernel"] = rl.compute_s / t_k if t_k else 0.0
        print(f"[ok] {mesh_kind}/{arch}/{shape_name}: "
              f"dominant={rl.dominant} useful={rl.useful_ratio:.2f} "
              f"hbm={hbm_gb:.2f}GiB wall={rec['wall_s']:.0f}s")
    except Exception as e:  # noqa
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["wall_s"] = time.time() - t0
        print(f"[FAIL] {mesh_kind}/{arch}/{shape_name}: {rec['error'][:300]}")
    out_path.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        cells = list(configs.all_cells())
    else:
        shapes = [args.shape] if args.shape else list(
            configs.shape_cells(args.arch))
        cells = [(args.arch, s) for s in shapes]

    failures = 0
    for mesh_kind in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, mesh_kind, force=args.force)
            failures += 1 if "error" in rec else 0
    print(f"done: {len(cells) * len(meshes)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
