"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0 per the assignment: feed-forward lives inside the xLSTM blocks
(mLSTM up/down projection, gated FFN in sLSTM blocks).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    slstm_every=8,        # 7:1 mLSTM:sLSTM
    subquadratic=True,
    source="arXiv:2405.04517",
)

SMOKE_CONFIG = CONFIG.replace(
    name="xlstm-1.3b-smoke", num_layers=4, d_model=128, num_heads=4,
    num_kv_heads=4, head_dim=32, vocab_size=512, slstm_every=2,
)
