"""Blocked online-softmax attention Pallas kernel (the framework's
perf-critical attention hot-spot; VMEM-tiled for TPU).

Grid: (batch*heads, q_blocks, kv_blocks) with the KV axis innermost so the
running max / denominator / accumulator live in VMEM scratch across KV
iterations (one-pass flash algorithm). Causal + sliding-window masks are
applied from block coordinates; fully-masked KV blocks still execute in this
baseline (the HLO-level block-skipping variant is a §Perf iteration).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_blocks, block_q, block_kv, scale, causal, window):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # [bq, d]
    k = k_ref[0].astype(jnp.float32)              # [bkv, d]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kb * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == kv_blocks - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_kv=128, interpret=False):
    """q: [B,H,S,D]; k,v: [B,H,T,D] -> [B,H,S,D]. H already KV-repeated."""
    b, h, s, d = q.shape
    t = k.shape[2]
    bq = min(block_q, s)
    bkv = min(block_kv, t)
    assert s % bq == 0 and t % bkv == 0
    grid = (b * h, s // bq, t // bkv)
    scale = 1.0 / (d ** 0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    kernel = functools.partial(
        _flash_kernel, kv_blocks=grid[2], block_q=bq, block_kv=bkv,
        scale=scale, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
