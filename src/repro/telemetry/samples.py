"""Columnar sample streams (the telemetry hot path).

A :class:`SampleBlock` holds one probe stream's reports as numpy columns —
timestamps, watts, per-sample integration dt — plus a uint8 **GPIO bitmask**
per sample instead of per-object string tuples: bit ``i`` set means GPIO
line ``i`` was high when the report was taken, exactly what the main board's
PIC sees. Because a line can be recycled between tag names over a run
(``TagBus`` frees lines on lower), each block also carries the line->name
mapping per *segment* of samples sharing one tag-bus epoch, captured at read
time — so bit resolution is stable even as the live bus moves on.

Energy reductions (``energy_j``, ``energy_by_tag``, the per-request
``split_energy`` share computation) are vectorized numpy expressions over
these columns — ~10x+ over the legacy per-``Sample`` Python loops — and a
lazy :meth:`SampleBlock.samples` view recovers the legacy ``Sample`` objects
(string tag tuples included) for back-compat without paying for them unless
asked.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

from repro.core.probe import AVG_N, REPORT_SPS, Sample


def _segment_epochs(epochs: np.ndarray) -> np.ndarray:
    """Offsets [k+1] of maximal runs of equal epoch values."""
    n = epochs.shape[0]
    if n == 0:
        return np.zeros(1, np.int64)
    cuts = np.flatnonzero(np.diff(epochs)) + 1
    return np.concatenate([[0], cuts, [n]]).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SampleBlock:
    """One stream's reports in columnar form.

    ``seg_bounds``/``seg_maps`` partition the samples into runs sharing one
    GPIO line->name mapping: ``seg_maps[k]`` applies to samples
    ``seg_bounds[k]:seg_bounds[k+1]``.
    """

    t: np.ndarray               # [n] report timestamps (s)
    volts: np.ndarray           # [n]
    watts: np.ndarray           # [n]
    dt: np.ndarray              # [n] integration period per report (s)
    bits: np.ndarray            # [n] uint8 GPIO bitmask at report time
    seg_bounds: np.ndarray      # [k+1] int64 offsets
    seg_maps: Tuple[Mapping[int, str], ...]   # [k] line -> tag name
    n_avg: int = AVG_N

    @property
    def n(self) -> int:
        return int(self.t.shape[0])

    def __len__(self) -> int:
        return self.n

    @classmethod
    def empty(cls) -> "SampleBlock":
        z = np.zeros(0)
        return cls(t=z, volts=z, watts=z, dt=z,
                   bits=np.zeros(0, np.uint8),
                   seg_bounds=np.zeros(1, np.int64), seg_maps=())

    @classmethod
    def from_columns(cls, t: np.ndarray, watts: np.ndarray, *,
                     volts: float, dt: float, bits: np.ndarray,
                     epochs: np.ndarray,
                     epoch_maps) -> "SampleBlock":
        """Assemble a block from raw probe columns + tag-index lookups."""
        bounds = _segment_epochs(epochs)
        maps = tuple(dict(epoch_maps(int(epochs[s]))) if epochs.shape[0] else {}
                     for s in bounds[:-1])
        return cls(t=np.asarray(t, np.float64),
                   volts=np.full(t.shape, volts),
                   watts=np.asarray(watts, np.float64),
                   dt=np.full(t.shape, dt),
                   bits=np.asarray(bits, np.uint8),
                   seg_bounds=bounds, seg_maps=maps)

    @classmethod
    def concat(cls, blocks: Sequence["SampleBlock"]) -> "SampleBlock":
        blocks = [b for b in blocks if b.n]
        if not blocks:
            return cls.empty()
        if len(blocks) == 1:
            return blocks[0]
        bounds, off, maps = [np.zeros(1, np.int64)], 0, []
        for b in blocks:
            bounds.append(b.seg_bounds[1:] + off)
            maps.extend(b.seg_maps)
            off += b.n
        return cls(
            t=np.concatenate([b.t for b in blocks]),
            volts=np.concatenate([b.volts for b in blocks]),
            watts=np.concatenate([b.watts for b in blocks]),
            dt=np.concatenate([b.dt for b in blocks]),
            bits=np.concatenate([b.bits for b in blocks]),
            seg_bounds=np.concatenate(bounds), seg_maps=tuple(maps))

    # -- vectorized reductions ----------------------------------------------

    @property
    def amps(self) -> np.ndarray:
        return np.divide(self.watts, self.volts,
                         out=np.zeros_like(self.watts),
                         where=self.volts != 0)

    def energy_j(self) -> float:
        """Integral of averaged power over each report's actual period."""
        return float(self.watts @ self.dt)

    def duration_s(self) -> float:
        return float(self.dt.sum())

    def avg_power_w(self) -> float:
        d = self.duration_s()
        return self.energy_j() / d if d > 0 else 0.0

    def tag_mask(self, name: str) -> np.ndarray:
        """Boolean [n]: samples taken while tag ``name`` was high."""
        out = np.zeros(self.n, bool)
        for k, m in enumerate(self.seg_maps):
            for idx, nm in m.items():
                if nm == name:
                    s, e = self.seg_bounds[k], self.seg_bounds[k + 1]
                    out[s:e] = (self.bits[s:e] >> idx) & 1
        return out

    def tag_names(self) -> Tuple[str, ...]:
        names = {nm for m in self.seg_maps for nm in m.values()}
        return tuple(sorted(names))

    def energy_by_tag(self) -> Dict[str, float]:
        """Per-tag energy: vectorized counterpart of the legacy
        ``MainBoard.energy_by_tag`` per-object loop."""
        e = self.watts * self.dt
        out: Dict[str, float] = {}
        for k, m in enumerate(self.seg_maps):
            if not m:
                continue
            s, end = self.seg_bounds[k], self.seg_bounds[k + 1]
            seg_bits, seg_e = self.bits[s:end], e[s:end]
            for idx, name in m.items():
                sel = (seg_bits >> idx) & 1
                if sel.any():
                    out[name] = out.get(name, 0.0) + float(seg_e @ sel)
        return out

    def split_energy(self, group_sizes: Mapping[str, int]) -> Dict[str, float]:
        """Equal-share attribution: each sample's energy splits evenly among
        all members of all listed tag groups active at that sample; returns
        each *tag's* aggregate share (divide by the group size for the
        per-member share). Matches the legacy per-sample loop exactly.
        """
        if not self.n or not group_sizes:
            return {}
        e = self.watts * self.dt
        sel = {name: self.tag_mask(name) for name in group_sizes}
        sharers = np.zeros(self.n, np.float64)
        for name, mask in sel.items():
            sharers += group_sizes[name] * mask
        safe = np.maximum(sharers, 1.0)
        return {name: float((e * mask * (group_sizes[name] / safe)).sum())
                for name, mask in sel.items()}

    # -- legacy view ---------------------------------------------------------

    def samples(self) -> "SampleView":
        """Lazy ``Sample``-object view (legacy string-tuple tags)."""
        return SampleView(self)


class SampleView(Sequence):
    """Lazy back-compat view of a :class:`SampleBlock` as ``Sample`` objects
    with resolved string tag tuples; materializes one object per access."""

    def __init__(self, block: SampleBlock):
        self._b = block

    def __len__(self) -> int:
        return self._b.n

    def _resolve_tags(self, i: int) -> Tuple[str, ...]:
        b = self._b
        k = int(np.searchsorted(b.seg_bounds, i, side="right")) - 1
        m = b.seg_maps[k] if 0 <= k < len(b.seg_maps) else {}
        bits = int(b.bits[i])
        return tuple(sorted(m[idx] for idx in m if bits & (1 << idx)))

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        b = self._b
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(i)
        volts = float(b.volts[i])
        watts = float(b.watts[i])
        return Sample(t=float(b.t[i]), volts=volts,
                      amps=round(watts / volts, 6) if volts else 0.0,
                      watts=watts, n_avg=b.n_avg,
                      tags=self._resolve_tags(i), dt=float(b.dt[i]))


def read_board_blocks(board, duration: float) -> Dict[int, SampleBlock]:
    """Columnar read of every probe on a :class:`MainBoard`: advances the
    board clock by ``duration`` and returns per-probe ``SampleBlock``s with
    GPIO bitmasks resolved through the tag bus's compiled interval index
    (one vectorized lookup per stream, not one replay per sample)."""
    t0 = board.now
    idx = board.tags.index()
    out: Dict[int, SampleBlock] = {}
    for pid, _, probe, sps in board.probes():
        t, watts = probe.read_block(t0, duration, sps=sps)
        bits, epochs = idx.states_at(t)
        out[pid] = SampleBlock.from_columns(
            t, watts, volts=probe.cfg.volts_nominal,
            dt=1.0 / sps if sps else 1.0 / REPORT_SPS,
            bits=bits, epochs=epochs, epoch_maps=idx.map_at)
    board.advance(duration)
    return out
