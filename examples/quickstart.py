"""Quickstart: build an assigned architecture (reduced config), train a few
steps on synthetic data, checkpoint, and serve a batch of requests.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.tracing import counting_jit
from repro.models import build_model
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import StepConfig, TrainState, make_train_step
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = configs.get_smoke("granite-20b")
    model = build_model(cfg, q_block=16)
    params, _ = model.init(jax.random.key(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

    # --- train a few steps ---
    state = TrainState(params, init_opt_state(params))
    step = counting_jit(
        make_train_step(model, OptConfig(lr=3e-3, warmup_steps=5),
                        StepConfig()),
        "quickstart_train_step", donate_argnums=(0,))
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      global_batch=4))
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        if i % 3 == 0:
            # dalek: allow[host-sync] demo prints the loss every 3rd step
            print(f"  step {i}: loss={float(metrics['loss']):.4f}")

    # --- serve with the trained weights ---
    engine = ServeEngine(model, state.params, batch_size=4, max_seq=96)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=8) for i in range(3)]
    stats = engine.serve(reqs)
    print(f"served 3 requests: {stats['tokens_decoded']} tokens, "
          f"{stats['decode_tok_per_s']:.1f} tok/s, "
          f"energy_by_tag={ {k: round(v,2) for k,v in stats['energy_by_tag'].items()} }")
    for r in reqs:
        print(f"  req {r.req_id}: {r.output}")


if __name__ == "__main__":
    main()
