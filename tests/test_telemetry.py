"""Unified telemetry API (`repro.telemetry`): MonitorSession invariants,
columnar-vs-legacy equivalence, TagBus channel recycling, and I2C bus
oversubscription fidelity. Each property is tied to a platform guarantee
the rest of the stack (train loop, serving engines) relies on."""
import numpy as np
import pytest

from repro.core.mainboard import BUS_MAX_SPS, MainBoard, PROBES_PER_BUS
from repro.core.probe import REPORT_SPS, Probe, ProbeConfig
from repro.core.tags import N_GPIO, TagBus
from repro.telemetry import (EnergyReport, ModelSource, MonitorSession,
                             MutableSource, SampleBlock, TraceExhausted,
                             TraceSource)


def _clock():
    """Manually advanced clock for standalone TagBus tests."""
    state = {"t": 0.0}

    def now():
        return state["t"]

    now.advance = lambda dt: state.__setitem__("t", state["t"] + dt)
    return now


# ---------------------------------------------------------------------------
# TagBus: channel recycling + compiled interval index


def test_tagbus_channels_recycle_after_release():
    bus = TagBus(clock=_clock())
    # far more distinct names than GPIO lines, sequentially: must not leak
    for i in range(3 * N_GPIO):
        with bus.tag(f"region_{i}"):
            pass
    # the 8-concurrent hardware limit still holds
    for i in range(N_GPIO):
        bus.raise_(f"c{i}")
    with pytest.raises(RuntimeError):
        bus.raise_("one_too_many")
    # lowering one frees its line for a brand-new name
    bus.lower("c3")
    bus.raise_("late_arrival")          # must not raise
    assert "late_arrival" in bus.active_now()


def test_tagbus_index_matches_brute_replay():
    rng = np.random.default_rng(0)
    clock = _clock()
    bus = TagBus(clock=clock)
    live = []
    for _ in range(200):
        clock.advance(float(rng.uniform(0.001, 0.01)))
        if live and rng.random() < 0.45:
            bus.lower(live.pop(rng.integers(len(live))))
        elif len(live) < N_GPIO:
            name = f"t{rng.integers(6)}_{rng.integers(1000)}"
            if name not in live:
                bus.raise_(name)
                live.append(name)

    def brute(t):
        high = {}
        for et, idx, name, up in bus._events:
            if et > t:
                break
            if up:
                high[idx] = name
            else:
                high.pop(idx, None)
        return tuple(sorted(high.values()))

    ts = rng.uniform(-0.01, clock() + 0.01, 300)
    for t in ts:
        assert bus.active_at(float(t)) == brute(float(t))


def test_tagbus_index_incremental_after_new_events():
    clock = _clock()
    bus = TagBus(clock=clock)
    bus.raise_("a")
    assert bus.active_at(clock()) == ("a",)     # compiles the index
    clock.advance(1.0)
    bus.lower("a")
    clock.advance(1.0)
    bus.raise_("b")                             # extends compiled timeline
    assert bus.active_at(0.5) == ("a",)
    assert bus.active_at(1.5) == ()
    assert bus.active_at(clock()) == ("b",)


# ---------------------------------------------------------------------------
# Columnar path vs legacy per-object path


def _twin_boards(noise_w=0.005):
    """Two boards with identically seeded probes: their reads are
    bit-equal, so the per-object and columnar paths can be compared."""
    a, b = MainBoard(), MainBoard()
    for mb in (a, b):
        mb.attach(Probe(lambda t: 90.0 + 20 * np.sin(40 * t),
                        ProbeConfig(noise_w=noise_w)))
    return a, b


def _scripted_reads(mb, reader):
    """Overlapping regions + tag recycling across several reads."""
    out = []
    with mb.tags.tag("outer"):
        out.append(reader(mb, 0.05))
        with mb.tags.tag("inner"):
            out.append(reader(mb, 0.031))
        out.append(reader(mb, 0.02))
    with mb.tags.tag("reused_line"):    # recycles the line "inner" used
        out.append(reader(mb, 0.04))
    return out


def test_bitmask_attribution_matches_string_tuples_bit_for_bit():
    mb_leg, mb_col = _twin_boards()
    legacy = _scripted_reads(mb_leg, lambda mb, d: mb.read_samples(d)[0])
    blocks = _scripted_reads(mb_col, lambda mb, d: mb.read_block(d)[0])
    for samples, block in zip(legacy, blocks):
        view = block.samples()
        assert len(view) == len(samples)
        for s_leg, s_col in zip(samples, view):
            assert s_col.t == s_leg.t
            assert s_col.watts == s_leg.watts          # bit-equal pipeline
            assert s_col.tags == s_leg.tags            # bitmask == tuples
        by_leg = MainBoard.energy_by_tag(samples)
        by_col = block.energy_by_tag()
        assert set(by_leg) == set(by_col)
        for k in by_leg:
            assert abs(by_leg[k] - by_col[k]) < 1e-9


def test_split_energy_matches_legacy_equal_share_loop():
    mb_leg, mb_col = _twin_boards()
    groups = {"outer": 3, "inner": 2, "reused_line": 1}
    legacy = [s for chunk in
              _scripted_reads(mb_leg, lambda mb, d: mb.read_samples(d)[0])
              for s in chunk]
    block = SampleBlock.concat(
        _scripted_reads(mb_col, lambda mb, d: mb.read_block(d)[0]))

    # reference: the old EngineTelemetry per-sample equal-share loop
    dt = 1.0 / REPORT_SPS
    want = {k: 0.0 for k in groups}
    for s in legacy:
        sharers = sum(groups[t] for t in s.tags if t in groups)
        if sharers:
            for t in s.tags:
                if t in groups:
                    want[t] += s.watts * dt * groups[t] / sharers

    got = block.split_energy(groups)
    for k in groups:
        assert abs(got.get(k, 0.0) - want[k]) < 1e-9
    # shares partition the energy of every sample carrying >=1 group tag
    tagged = block.tag_mask("outer") | block.tag_mask("inner") \
        | block.tag_mask("reused_line")
    tagged_j = float((block.watts * block.dt)[tagged].sum())
    assert abs(sum(got.values()) - tagged_j) < 1e-9


def test_per_tag_energy_bounded_by_total():
    rng = np.random.default_rng(1)
    src = MutableSource(0.0)
    session = MonitorSession(src, node="prop")
    for step in range(12):
        src.set(float(rng.uniform(10.0, 200.0)))
        tags = [f"r{j}" for j in range(rng.integers(0, 4))]
        for t in tags:
            session.tags.raise_(t)
        session.sample(float(rng.uniform(0.003, 0.05)))
        for t in reversed(tags):
            session.tags.lower(t)
    rep = session.report()
    assert rep.energy_j > 0
    for tag, e in rep.by_tag.items():
        assert 0.0 <= e <= rep.energy_j + 1e-9, tag


# ---------------------------------------------------------------------------
# MonitorSession: grid alignment, windows, reports


def test_window_alignment_residual_within_one_sample_period():
    rng = np.random.default_rng(2)
    session = MonitorSession(MutableSource(100.0), node="grid")
    n_total = 0
    for _ in range(40):
        wall = float(rng.uniform(0.0001, 0.0123))   # mostly off-grid
        block = session.sample(wall)
        n_total += block.n
        # cumulative sampled time never drifts more than one period from
        # cumulative wall time (fractions roll into the next window)
        residual = abs(session.cursor - n_total / REPORT_SPS)
        assert residual <= 1.0 / REPORT_SPS + 1e-12
    assert n_total == round(session.cursor * REPORT_SPS)


def test_session_window_scopes_report():
    src = MutableSource(50.0)
    session = MonitorSession(src, probe_cfg=ProbeConfig(noise_w=0.0))
    session.sample(0.05)
    with session.window() as w:
        src.set(200.0)
        session.sample(0.1)
    src.set(50.0)
    session.sample(0.05)
    rep = w.report(tokens=10)
    assert rep.n_samples == 100
    assert abs(rep.duration_s - 0.1) < 1e-9
    assert abs(rep.energy_j - 20.0) < 0.1          # 200 W * 0.1 s
    assert abs(rep.j_per_token - rep.energy_j / 10) < 1e-12
    total = session.report()
    assert abs(total.energy_j - (20.0 + 2 * 2.5)) < 0.2
    assert total.n_samples == 200
    # O(1) running total agrees with the full reduction
    assert abs(session.energy_j() - total.energy_j) < 1e-12


def test_session_region_tags_samples():
    src = MutableSource(100.0)
    session = MonitorSession(src, probe_cfg=ProbeConfig(noise_w=0.0))
    with session.region("fwd"):
        session.sample(0.1)
    session.sample(0.1)
    rep = session.report()
    assert abs(rep.by_tag["fwd"] - 10.0) < 1e-6
    assert abs(rep.energy_j - 20.0) < 1e-6
    assert isinstance(rep, EnergyReport)


def test_session_reset_clears_samples_keeps_clock():
    session = MonitorSession(MutableSource(10.0))
    session.sample(0.1)
    cursor = session.cursor
    session.reset()
    assert session.cursor == cursor
    assert session.report().energy_j == 0.0
    assert session.energy_j() == 0.0
    session.sample(0.1)
    assert session.report().n_samples == 100


# ---------------------------------------------------------------------------
# Sources


def test_model_source_idles_between_steps():
    class _PM:                                   # stands in for ServePowerModel
        def idle_power_w(self):
            return 7.0

        def trace(self, n_tokens, wall_s):
            return lambda t: np.full(np.shape(t), 40.0) if np.ndim(t) else 40.0

    src = ModelSource(_PM())
    assert src(0.5) == 7.0
    assert np.all(src(np.array([0.1, 0.2])) == 7.0)
    src.set_step(4, 1.0, t0=10.0)
    assert float(np.asarray(src(10.5))) == 40.0
    src.clear()
    assert src(10.5) == 7.0


def test_trace_source_round_trips_a_block():
    src = MutableSource(123.0)
    session = MonitorSession(src, probe_cfg=ProbeConfig(noise_w=0.0))
    block = session.sample(0.05)
    replay = TraceSource.from_block(block)
    assert abs(replay(0.001) - 123.0) < 1e-6
    assert np.allclose(replay(block.t), block.watts)
    with pytest.raises(TraceExhausted):            # past the recording
        replay(99.0)


def test_trace_source_exhaustion_modes():
    t = np.array([0.1, 0.2, 0.3])
    w = np.array([10.0, 20.0, 30.0])
    with pytest.raises(TraceExhausted):
        TraceSource(t, w)(0.31)
    with pytest.raises(TraceExhausted):            # any element past the end
        TraceSource(t, w)(np.array([0.05, 0.5]))
    assert TraceSource(t, w)(0.3) == 30.0          # the end itself is in range
    assert TraceSource(t, w, on_exhausted="hold")(99.0) == 30.0
    assert TraceSource(t, w, fill_w=7.0, on_exhausted="fill")(99.0) == 7.0
    # loop wraps modulo the final timestamp (trace anchored at t=0)
    looped = TraceSource(t, w, on_exhausted="loop")
    assert looped(0.3 + 0.15) == looped(0.15) == 20.0
    with pytest.raises(TraceExhausted):            # empty trace: nothing to replay
        TraceSource(np.zeros(0), np.zeros(0))(0.0)
    with pytest.raises(ValueError):
        TraceSource(t, w, on_exhausted="banana")


# ---------------------------------------------------------------------------
# Bus oversubscription fidelity


def test_oversubscribed_bus_degrades_per_probe_rate():
    mb = MainBoard()
    n = PROBES_PER_BUS + 2                          # 8 probes on one chain
    for i in range(n):
        mb.attach(Probe(lambda t: 100.0, ProbeConfig(probe_id=i, noise_w=0.0)),
                  bus=0, oversubscribe=True)
    sps = mb.effective_sps(0)
    assert sps == BUS_MAX_SPS / n < REPORT_SPS      # I2C budget shared
    blocks = mb.read_block(1.0)
    assert len(blocks) == n
    for b in blocks.values():
        assert b.n == round(sps)                    # degraded report count
        # energy integrates with the stream's actual dt, not 1/REPORT_SPS
        assert np.allclose(b.dt, 1.0 / sps)
        assert abs(b.energy_j() - 100.0) < 0.5      # 100 W * 1 s
    legacy = MainBoard()
    for i in range(n):
        legacy.attach(Probe(lambda t: 100.0,
                            ProbeConfig(probe_id=i, noise_w=0.0)),
                      bus=0, oversubscribe=True)
    stream = legacy.read_samples(1.0)[0]
    assert len(stream) == round(sps)
    assert abs(MainBoard.energy_j(stream) - 100.0) < 0.5


def test_single_sample_stream_integrates_actual_dt():
    """Even a one-sample read carries the degraded stream's dt (it cannot
    be inferred from timestamp spacing)."""
    mb = MainBoard()
    n = PROBES_PER_BUS + 2
    for i in range(n):
        mb.attach(Probe(lambda t: 100.0, ProbeConfig(probe_id=i, noise_w=0.0)),
                  bus=0, oversubscribe=True)
    sps = mb.effective_sps(0)
    stream = mb.read_samples(1.0 / sps)[0]
    assert len(stream) == 1
    assert stream[0].dt == 1.0 / sps
    assert abs(MainBoard.energy_j(stream) - 100.0 / sps) < 1e-6


def test_tag_index_snapshot_survives_later_events():
    """A compiled TagIndex is an immutable snapshot: answers don't change
    as the bus keeps logging (even across internal buffer regrowth)."""
    clock = _clock()
    bus = TagBus(clock=clock)
    bus.raise_("early")
    clock.advance(1.0)
    bus.lower("early")
    snap = bus.index()
    before = [snap.active_at(t) for t in (0.5, 1.5)]
    for i in range(40):                         # force buffer regrowth
        clock.advance(0.1)
        with bus.tag(f"later_{i}"):
            pass
    assert [snap.active_at(t) for t in (0.5, 1.5)] == before == [("early",), ()]
    assert bus.active_at(0.5) == ("early",)     # fresh index agrees


def test_full_bus_still_rejects_without_oversubscribe():
    mb = MainBoard()
    for i in range(PROBES_PER_BUS):
        mb.attach(Probe(lambda t: 1.0, ProbeConfig(probe_id=i)), bus=0)
    with pytest.raises(RuntimeError):
        mb.attach(Probe(lambda t: 1.0), bus=0)
    assert mb.effective_sps(0) == REPORT_SPS        # six probes: full rate
