"""Training loop with the paper's energy platform as a first-class citizen.

Integrates: data prefetch, jitted train step, atomic async checkpoints,
region-tagged energy telemetry (a ``repro.telemetry`` ``MonitorSession``
over the probe/main-board pipeline), DVFS power capping, and fault-tolerant
restart (resume from the newest committed checkpoint + step-indexed data).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_mod
from repro.core import energy as energy_mod
from repro.core.hw import TPU_V5E
from repro.data.pipeline import Prefetcher
from repro.obs import NULL_SPAN, MetricsRegistry, Tracer
from repro.telemetry import MonitorSession, MutableSource


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    log_every: int = 10
    power_cap_w: Optional[float] = None
    n_chips: int = 1


def make_session(dev=TPU_V5E, node: str = "train-node"):
    """Training telemetry: a ``MonitorSession`` over a host-updated power
    source. Each step derives node power from the measured step time and
    the roofline terms (utilization model), sets it on the source, and
    samples the 1000 SPS pipeline — tag-level attribution works exactly as
    on DALEK."""
    source = MutableSource(dev.idle_w)
    return MonitorSession(source, node=node), source


def run(train_step, state, data, loop_cfg: LoopConfig,
        shardings=None, batch_shardings=None,
        roofline_terms: Optional[Dict[str, float]] = None,
        on_step: Optional[Callable] = None,
        tracer: Optional[Tracer] = None,
        metrics_registry: Optional[MetricsRegistry] = None):
    """Run training; returns (state, history, summary).

    ``tracer``/``metrics_registry`` plug the loop into the unified
    observability layer: one ``train_step`` span per step (referencing its
    energy sample window for the timeline export), ``checkpoint`` spans,
    and registry-backed counters the launcher can snapshot to JSON."""
    session, power = make_session()
    m = metrics_registry if metrics_registry is not None else MetricsRegistry()
    dev = TPU_V5E
    saver = ckpt_mod.AsyncSaver()
    start_step = 0
    if loop_cfg.ckpt_dir:
        ckpt_mod.gc_partial(loop_cfg.ckpt_dir)
        steps = ckpt_mod.valid_steps(loop_cfg.ckpt_dir)
        if steps:
            state, manifest = ckpt_mod.restore(
                state, loop_cfg.ckpt_dir, shardings=shardings)
            start_step = manifest["step"]

    dvfs = None
    if loop_cfg.power_cap_w is not None and roofline_terms is not None:
        dvfs = energy_mod.cap_frequency(loop_cfg.power_cap_w, roofline_terms)

    prefetch = Prefetcher(data, start_step=start_step,
                          shardings=batch_shardings)
    history = []
    tokens_seen = 0
    try:
        for step in range(start_step, loop_cfg.total_steps):
            idx, batch = prefetch.next()
            assert idx == step, (idx, step)
            step_cm = (tracer.span("train_step", track="train", step=step + 1)
                       if tracer is not None
                       else contextlib.nullcontext(NULL_SPAN))
            t0 = time.perf_counter()
            with step_cm as sp, session.region("train_step"):
                state, metrics = train_step(state, batch)
                metrics = jax.tree.map(
                    lambda x: np.asarray(jax.device_get(x)), metrics)
                wall = time.perf_counter() - t0
                util = 1.0
                if roofline_terms:
                    t_pred = energy_mod.step_time_s(roofline_terms, dvfs)
                    util = min(roofline_terms["compute"] / max(t_pred, 1e-9), 1.0)
                # sample the probes across the step's wall time while the
                # GPIO tag is high (paper: tag-synchronized measurement)
                power.set(energy_mod.power_w(dev, util, dvfs))
                sp.set("window", session.n_windows)
                session.sample(wall)
            n_batch_tokens = int(np.prod(batch["tokens"].shape))
            tokens_seen += n_batch_tokens
            m.histogram("train_step_s",
                        "train step wall seconds").observe(wall)
            m.counter("train_tokens").inc(n_batch_tokens)
            m.gauge("train_energy_j",
                    "session joules so far (all chips)").set(
                session.energy_j() * loop_cfg.n_chips)
            rec = {"step": step + 1, "wall_s": wall,
                   "loss": float(metrics.get("loss", np.nan)),
                   "grad_norm": float(metrics.get("grad_norm", np.nan)),
                   "energy_j": session.energy_j() * loop_cfg.n_chips,
                   "tokens": tokens_seen}
            history.append(rec)
            if on_step:
                on_step(rec)
            if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
                ck_cm = (tracer.span("checkpoint", track="train",
                                     step=step + 1)
                         if tracer is not None
                         else contextlib.nullcontext(NULL_SPAN))
                with ck_cm, session.region("checkpoint"):
                    saver.save(state, loop_cfg.ckpt_dir, step + 1)
                ckpt_mod.prune(loop_cfg.ckpt_dir, loop_cfg.ckpt_keep)
                m.counter("checkpoints_saved").inc()
        if loop_cfg.ckpt_dir:
            saver.save(state, loop_cfg.ckpt_dir, loop_cfg.total_steps)
            saver.wait()
    finally:
        prefetch.close()
    report = session.report(tokens=tokens_seen)
    summary = {
        "energy_j": report.energy_j * loop_cfg.n_chips,
        "energy_by_tag": dict(report.by_tag),
        # all-chip average power, consistent with the scaled energy_j
        "avg_power_w": report.avg_power_w * loop_cfg.n_chips,
        "tokens": tokens_seen,
        "j_per_token": (report.energy_j * loop_cfg.n_chips
                        / max(tokens_seen, 1)),
        "metrics": m.snapshot(),
        # the live session rides along (non-JSON) so callers can merge the
        # span stream with its energy windows in the timeline export
        "session": session,
    }
    return state, history, summary
