import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: compile a (cell × variant), record the three
roofline terms, and append to the iteration log.

    PYTHONPATH=src python -m repro.launch.perf --arch granite-20b \
        --shape train_4k --variant layout=fsdp,cast_once=1 --tag zero3
"""
import argparse
import json
import pathlib
import time

import jax

from repro import configs
from repro.configs.base import SHAPES
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.perf import hlo_analysis, roofline

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "perf"


def parse_variant(s):
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, v = kv.split("=")
        if v.isdigit():
            v = int(v)
        elif v in ("true", "false"):
            v = v == "true"
        out[k] = v
    for b in ("cast_once", "barrier"):
        if b in out:
            out[b] = bool(out[b])
    return out


def run_variant(arch, shape_name, variant, tag, mesh_kind="single",
                save_hlo=None):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    pod_block = 256 if mesh_kind == "multi" else None
    t0 = time.time()
    jitted, args, cfg, shape, info = build_cell(arch, shape_name, mesh,
                                                variant)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    q_block = 512
    t_kv = shape.seq_len + (cfg.stub_prefix_len if cfg.family == "vlm" else 0)
    analysis = hlo_analysis.analyze(compiled, pod_block,
                                    fused_attn_shapes=(q_block, t_kv))
    if save_hlo:
        pathlib.Path(save_hlo).write_text(compiled.as_text())
    params_sds = args[0].params if shape.kind == "train" else args[0]
    n_total = roofline.count_params(params_sds)
    n_active = roofline.active_params(cfg, n_total)
    mf = roofline.model_flops(cfg, shape, n_active)
    rl = roofline.compute_roofline(analysis, mesh.devices.size, mf)
    # "with flash kernel": score buffers live in VMEM on the TPU deployment
    mem_kernel_s = (analysis["bytes_accessed"]
                    - analysis["attn_score_bytes"]) / roofline.HBM_BW
    t_step = max(rl.compute_s, rl.memory_s, rl.collective_s)
    t_step_k = max(rl.compute_s, mem_kernel_s, rl.collective_s)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "variant": variant, "info": info,
        "roofline": rl.to_dict(),
        "t_step_overlap_s": t_step,
        "roofline_frac": rl.compute_s / t_step if t_step else 0.0,
        "memory_s_with_kernel": mem_kernel_s,
        "roofline_frac_with_kernel": rl.compute_s / t_step_k if t_step_k else 0.0,
        "hbm_gb": (analysis["memory"]["argument_bytes"]
                   + analysis["memory"]["temp_bytes"]) / 2**30,
        "hbm_adjusted_gb": (analysis["memory"]["argument_bytes"]
                            + analysis["memory"]["temp_bytes"]
                            - analysis.get("f32_hoist_bytes", 0.0)) / 2**30,
        "collectives": analysis["collectives"],
        "wall_s": time.time() - t0,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{arch}__{shape_name}__{tag}.json"
    out.write_text(json.dumps(rec, indent=2, default=float))
    print(f"[{tag}] {arch}/{shape_name}: compute={rl.compute_s:.2f}s "
          f"memory={rl.memory_s:.2f}s (kernel:{mem_kernel_s:.2f}s) "
          f"collective={rl.collective_s:.2f}s "
          f"dom={rl.dominant} frac={rec['roofline_frac']:.3f} "
          f"(kernel:{rec['roofline_frac_with_kernel']:.3f}) "
          f"hbm={rec['hbm_gb']:.1f}GiB useful={rl.useful_ratio:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()
    run_variant(args.arch, args.shape, parse_variant(args.variant), args.tag,
                args.mesh, args.save_hlo)


if __name__ == "__main__":
    main()
