"""DLK010 dtype-drift — the PR 9 retrace bug class.

``init_cache`` allocates carried state in one dtype (float32); if a step
function returns the carry after casting it into the *activation* dtype
(``state.astype(x.dtype)`` for the concat, then returning a slice of the
result), the carry's abstract signature changes between step 1 and step 2
and the fused decode step retraces — one silent recompile per model
family, exactly what ``xlstm._causal_conv`` did before the pin.

The rule runs a per-function three-value lattice over names:

* ``CARRY`` — a parameter whose name looks like carried state
  (``state``/``carry``/``cache``), or a value pinned back to one
  (``v.astype(<carry>.dtype)``);
* ``DRIFT`` — a carry-derived value cast to a *non-carry* dtype
  (``state.astype(x.dtype)``), propagated through dtype-preserving ops
  (concatenate/where/pad/…, subscripts, arithmetic);
* ``OTHER`` — everything else, including explicit literal-dtype casts
  (``.astype(jnp.float32)``: the author pinned a concrete dtype on
  purpose) and calls the lattice does not model.

Returning a ``DRIFT`` value is the hazard: the fix is
``new_state.astype(state.dtype)`` (pin to the init dtype) before the
return. Fix-only policy, like DLK001: drift findings must be fixed or
pragma-justified, never baselined.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator

from repro.analysis.core import (Finding, ModuleContext, Rule, qualname,
                                 register)

OTHER, CARRY, DRIFT = 0, 1, 2

#: parameter-name fragments that mark carried state
CARRYISH = ("state", "carry", "cache")

#: ops that keep their (widest) input dtype — drift flows through them
_PRESERVING = {"concatenate", "stack", "where", "pad", "roll", "flip",
               "maximum", "minimum", "dynamic_update_slice", "expand_dims",
               "squeeze", "reshape", "transpose", "broadcast_to", "clip",
               "flipud", "fliplr", "tile", "repeat"}


def _carry_params(fn: ast.FunctionDef):
    args = fn.args
    return {a.arg for a in args.posonlyargs + args.args
            if a.arg not in ("self", "cls")
            and any(t in a.arg.lower() for t in CARRYISH)}


def _is_literal_dtype(node) -> bool:
    """``jnp.float32`` / ``np.dtype("bf16")`` / ``"float32"`` — an explicit
    concrete dtype, not one borrowed from another array."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    qn = qualname(node.func if isinstance(node, ast.Call) else node)
    leaf = qn.rsplit(".", 1)[-1] if qn else ""
    return leaf.startswith(("float", "bfloat", "int", "uint", "bool",
                            "complex", "dtype"))


class _Lattice:
    def __init__(self, env: Dict[str, int]):
        self.env = env

    def eval(self, node) -> int:
        if isinstance(node, ast.Name):
            return self.env.get(node.id, OTHER)
        if isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
            return self.eval(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, ast.BinOp):
            # promotion keeps the widest dtype; mixing a carry into
            # arithmetic is not (by itself) a drift
            return max(self.eval(node.left), self.eval(node.right))
        if isinstance(node, ast.IfExp):
            return max(self.eval(node.body), self.eval(node.orelse))
        if isinstance(node, (ast.Tuple, ast.List)):
            return max((self.eval(e) for e in node.elts), default=OTHER)
        if isinstance(node, ast.Call):
            return self._eval_call(node)
        return OTHER

    def _eval_call(self, call: ast.Call) -> int:
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr == "astype" and call.args:
            target = call.args[0]
            if isinstance(target, ast.Attribute) and target.attr == "dtype":
                if self.eval(target.value) == CARRY:
                    return CARRY            # pinned back to the carry dtype
                if self.eval(f.value) in (CARRY, DRIFT):
                    return DRIFT            # carry cast to a foreign dtype
                return OTHER
            if _is_literal_dtype(target):
                return OTHER                # concrete dtype chosen on purpose
            if isinstance(target, ast.Name) \
                    and self.env.get(target.id, OTHER) == CARRY:
                return CARRY                # dt = state.dtype; v.astype(dt)
            if self.eval(f.value) in (CARRY, DRIFT):
                return DRIFT
            return OTHER
        qn = qualname(f)
        leaf = f.attr if isinstance(f, ast.Attribute) \
            else (qn.rsplit(".", 1)[-1] if qn else "")
        if leaf in _PRESERVING:
            status = max((self.eval(a) for a in call.args), default=OTHER)
            return max(status,
                       max((self.eval(kw.value) for kw in call.keywords),
                           default=OTHER))
        if isinstance(f, ast.Attribute) and leaf in ("set", "add", "min",
                                                     "max"):
            return self.eval(f.value)       # ck.at[i].set(v) keeps ck's dtype
        return OTHER


@register
class DtypeDrift(Rule):
    """Carry returned in a drifted dtype — forces a decode retrace."""

    code = "DLK010"
    name = "dtype-drift"
    skip_tests = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn in ctx.functions:
            carry = _carry_params(fn)
            if not carry:
                continue
            env = {p: CARRY for p in carry}
            lat = _Lattice(env)
            assigns = sorted(
                (n for n in ast.walk(fn)
                 if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign))
                 and ctx.enclosing_function(n) is fn),
                key=lambda n: (n.lineno, n.col_offset))
            for node in assigns:
                if isinstance(node, ast.AugAssign):
                    if isinstance(node.target, ast.Name):
                        env[node.target.id] = max(
                            env.get(node.target.id, OTHER),
                            lat.eval(node.value))
                    continue
                value = node.value
                if value is None:
                    continue
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    if isinstance(tgt, (ast.Tuple, ast.List)) \
                            and isinstance(value, (ast.Tuple, ast.List)) \
                            and len(tgt.elts) == len(value.elts):
                        for t, v in zip(tgt.elts, value.elts):
                            if isinstance(t, ast.Name):
                                env[t.id] = lat.eval(v)
                    else:
                        status = lat.eval(value)
                        elts = tgt.elts if isinstance(
                            tgt, (ast.Tuple, ast.List)) else [tgt]
                        for t in elts:
                            if isinstance(t, ast.Name):
                                env[t.id] = status
            for node in ast.walk(fn):
                if not isinstance(node, ast.Return) or node.value is None:
                    continue
                if ctx.enclosing_function(node) is not fn:
                    continue
                v = node.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
                for e in elts:
                    if lat.eval(e) != DRIFT:
                        continue
                    # only the carried slot of the return is the hazard: a
                    # drift-derived *activation* (e.g. `out = xp * w`) has a
                    # stable dtype and never feeds the next step's carry
                    if isinstance(e, ast.Name) and not any(
                            t in e.id.lower() for t in CARRYISH):
                        continue
                    label = e.id if isinstance(e, ast.Name) \
                        else "a carry value"
                    yield ctx.finding(
                        self, node,
                        f"'{fn.name}' returns {label} cast to a "
                        "non-carry dtype (via .astype(<activation>"
                        ".dtype)) — the carried state's abstract "
                        "signature changes on the next step and the "
                        "fused step retraces; pin it with "
                        ".astype(<carry>.dtype) before returning")
                    break
