"""Deterministic replay of recorded ``.dkt`` traces.

Recorded power turns into a regression instrument three ways:

``replay_attribution``  re-drive a recorded serving session window by
                        window: rebuild the session around a ``TraceSource``
                        of the recorded watts (noise-free probe, same report
                        grid, same clock origin), re-raise the recorded
                        tags, and recompute the per-request equal-share
                        energy split. Because the probe pipeline is
                        quantization-idempotent, the replayed stream is
                        bit-equal to the recording and the per-request
                        joules match the live run exactly.
``replay_policy``       drive the serve ``AdmissionController`` (DVFS
                        capping, TTL shed, injectable overrides) through a
                        deterministic tick simulation whose energy comes
                        from the recorded streams — swap policies, diff the
                        resulting ``PolicyResult`` rows.
``replay_cluster``      feed the recorded per-node power into
                        ``ClusterManager.submit``/``advance`` so scheduler
                        and quota experiments debit *measured* joules
                        instead of TDP guesses.

Everything is a pure function of (trace bytes, workload, policy): no wall
clock, no RNG — the same trace yields the same ``ReplayReport`` every time.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.manager import ClusterManager
from repro.cluster.topology import Topology
from repro.core.probe import ProbeConfig
from repro.obs import coerce_event
from repro.core.scheduler import ThroughputStats
from repro.serve.queue import AdmissionController
from repro.telemetry import MonitorSession, SampleBlock, TraceSource
from repro.tracestore.io import TraceReader


# ---------------------------------------------------------------------------
# typed results


@dataclasses.dataclass(frozen=True)
class PolicyResult:
    """One admission policy's outcome against one recorded trace."""

    policy: str
    energy_j: float                      # trace energy over the replayed span
    attributed_j: float                  # share landed on requests
    completed: int
    shed: int
    tokens: int
    duration_s: float
    per_request_j: Tuple[Tuple[int, float], ...]   # (req_id, J) sorted
    dvfs_f_ghz: Optional[float] = None

    @property
    def j_per_token(self) -> float:
        return self.attributed_j / self.tokens if self.tokens else 0.0


@dataclasses.dataclass(frozen=True)
class ClusterJobResult:
    job_id: int
    user: str
    state: str
    energy_j: float
    start_t: float
    end_t: float


@dataclasses.dataclass(frozen=True)
class ReplayReport:
    """Typed summary of a replay run (deterministic per trace+workload)."""

    trace_path: str
    n_streams: int
    n_samples: int
    duration_s: float
    results: Tuple[PolicyResult, ...] = ()
    cluster_jobs: Tuple[ClusterJobResult, ...] = ()

    def result(self, policy: str) -> PolicyResult:
        for r in self.results:
            if r.policy == policy:
                return r
        raise KeyError(f"no policy {policy!r} in report")

    def deltas(self, base: str, other: str) -> Dict[str, float]:
        """Deltas of ``other`` relative to ``base`` — the numbers an
        admission-policy regression test asserts on. Keys mirror the
        ``PolicyResult`` fields: ``energy_j`` is the trace energy over each
        policy's replayed span, ``attributed_j`` the share landed on
        requests."""
        a, b = self.result(base), self.result(other)
        return {
            "energy_j": b.energy_j - a.energy_j,
            "attributed_j": b.attributed_j - a.attributed_j,
            "shed": b.shed - a.shed,
            "completed": b.completed - a.completed,
            "j_per_token": b.j_per_token - a.j_per_token,
        }


# ---------------------------------------------------------------------------
# source / session reconstruction (the import hooks)


def rebuild_sources(reader: TraceReader,
                    on_exhausted: str = "raise") -> Dict[str, List[TraceSource]]:
    """Per-node ``TraceSource`` lists, one per recorded stream (chip)."""
    out: Dict[str, List[TraceSource]] = {}
    for s in reader.streams:
        block = reader.read(s["id"])
        out.setdefault(s.get("node", "node"), []).append(
            TraceSource.from_block(block, on_exhausted=on_exhausted))
    return out


def node_power_fn(reader: TraceReader, node: str,
                  on_exhausted: str = "hold",
                  sources: Optional[Dict[str, List[TraceSource]]] = None
                  ) -> Callable:
    """power(t) summing the node's recorded chip streams (cluster replay).
    Pass a prebuilt ``rebuild_sources`` map when calling per node — each
    default call decodes the whole file."""
    srcs = (sources if sources is not None
            else rebuild_sources(reader, on_exhausted)).get(node)
    if not srcs:
        raise KeyError(f"no streams recorded for node {node!r}")
    return lambda t: float(sum(s(t) for s in srcs))


class WindowedTraceSource:
    """Replays one recorded sampling window at a time (``ModelSource``
    style: the host installs the next window before each ``sample`` call).

    A single whole-stream zero-order hold is *not* bit-exact at window
    boundaries: the session's grid carry can leave consecutive windows
    overlapping by less than the probe's raw averaging span (AVG_N/RAW_SPS
    = 0.75 ms), so a report near a boundary would average in the previous
    window's last value. Scoping the hold to the current window's reports
    makes every averaged report reproduce its recorded value exactly.
    """

    def __init__(self):
        self._trace: Optional[TraceSource] = None

    def set_window(self, block: SampleBlock):
        self._trace = (TraceSource.from_block(block, on_exhausted="hold")
                       if block.n else None)

    def __call__(self, t):
        if self._trace is None:
            return np.zeros(np.shape(t)) if np.ndim(t) else 0.0
        return self._trace(t)


def replay_session(reader: TraceReader, stream_id: Optional[int] = None,
                   source=None) -> MonitorSession:
    """Rebuild a ``MonitorSession`` around a recorded stream: noise-free
    probe at the recorded volts, the recorded report grid, and the recorded
    clock origin. Default source is a whole-stream ``TraceSource``; pass
    ``source`` (e.g. a :class:`WindowedTraceSource`) to control replay
    granularity."""
    sid = stream_id if stream_id is not None else reader.stream_ids()[0]
    s = reader.stream(sid)
    if source is None:
        source = TraceSource.from_block(reader.read(sid), on_exhausted="raise")
    cfg = ProbeConfig(noise_w=0.0, volts_nominal=s.get("volts", 20.0))
    return MonitorSession(source, node=s.get("node", "replay"),
                          clock_t0=reader.meta.get("clock_t0", 0.0),
                          probe_cfg=cfg,
                          grid_sps=reader.meta.get("grid_sps",
                                                   s.get("sps", 1000.0)))


def replay_attribution(reader: TraceReader,
                       stream_id: Optional[int] = None) -> Dict[int, float]:
    """Recompute per-request energy attribution from a recorded serving
    session (``recorder.record_engine``): replay every logged telemetry
    event (phase, wall seconds, slot-tag -> request ids) through a rebuilt
    session — window by window against the recorded power — and split each
    window's energy exactly as the live engine did. The replayed stream is
    bit-equal to the recording (quantization-idempotent probe pipeline), so
    the returned {req_id: joules} reproduces the live attribution exactly.
    """
    events = [coerce_event(e) for e in reader.meta.get("events", [])]
    if not events:
        raise ValueError(
            f"{reader.path} has no telemetry event log — record the run "
            f"with tracestore.recorder.record_engine")
    sid = stream_id if stream_id is not None else reader.stream_ids()[0]
    source = WindowedTraceSource()
    session = replay_session(reader, sid, source=source)
    windows = reader.blocks(sid)
    per_req: Dict[int, float] = {}
    for ev in events:
        groups = ev.groups
        source.set_window(next(windows, SampleBlock.empty()))
        block = session.sample(ev.wall_s,
                               tags=[ev.phase] + sorted(groups))
        per_tag = block.split_energy({tg: len(ids)
                                      for tg, ids in groups.items()})
        for tg, ids in groups.items():
            share = per_tag.get(tg, 0.0) / len(ids)
            if share:
                for rid in ids:
                    per_req[rid] = per_req.get(rid, 0.0) + share
    return per_req


# ---------------------------------------------------------------------------
# policy replay (admission control against recorded power)


@dataclasses.dataclass
class ReplayRequest:
    """A workload row for policy replay (no token ids — the model does not
    rerun; only admission, occupancy, and energy attribution do)."""

    req_id: int
    max_new_tokens: int = 16
    ttl_s: Optional[float] = None
    arrival_s: float = 0.0
    prompt_tokens: int = 0       # prefill cost in the shed walk (live parity)
    # filled by the simulation
    n_generated: int = 0
    energy_j: float = 0.0
    done: bool = False
    finish_reason: str = ""


class EnergyTimeline:
    """Cumulative-energy index over recorded streams: O(log n) exact
    integral of recorded power over any [a, b) window. Build once per
    trace and share across policy replays — construction decodes and
    sorts every selected stream."""

    def __init__(self, blocks: Sequence[SampleBlock]):
        ts, es = [], []
        for b in blocks:
            if b.n:
                ts.append(np.asarray(b.t))
                es.append(np.asarray(b.watts) * np.asarray(b.dt))
        if ts:
            t = np.concatenate(ts)
            e = np.concatenate(es)
            order = np.argsort(t, kind="stable")
            self._t = t[order]
            self._cum = np.concatenate([[0.0], np.cumsum(e[order])])
        else:
            self._t = np.zeros(0)
            self._cum = np.zeros(1)
        self.total_j = float(self._cum[-1])
        self.t_end = float(self._t[-1]) if self._t.shape[0] else 0.0

    def window_j(self, a: float, b: float) -> float:
        """Energy of reports with timestamp in (a, b]."""
        lo = int(np.searchsorted(self._t, a, side="right"))
        hi = int(np.searchsorted(self._t, b, side="right"))
        return float(self._cum[hi] - self._cum[lo])


def replay_policy(reader: TraceReader, workload: Sequence[ReplayRequest],
                  admission: Optional[AdmissionController] = None,
                  name: str = "baseline", *, batch_size: int = 4,
                  step_s: float = 0.01, node: Optional[str] = None,
                  tokens_per_step: int = 1,
                  timeline: Optional[EnergyTimeline] = None) -> PolicyResult:
    """Deterministic tick simulation of the admission pipeline against a
    recorded trace.

    Each ``step_s`` tick: arrivals join the queue, the TTL shed walk runs
    (mirroring ``ContinuousEngine._shed_stale``), free slots admit under
    the policy, every active request generates ``tokens_per_step`` tokens,
    and the tick's *recorded* energy is split equally among active
    requests. Throughput statistics are fed from the simulated token flow,
    so ``should_shed`` sees the same signal shape as the live engine —
    minus the wall-clock jitter.
    """
    adm = admission or AdmissionController(stats=ThroughputStats())
    if timeline is None:
        streams = [s["id"] for s in reader.streams
                   if node is None or s.get("node") == node]
        timeline = EnergyTimeline([reader.read(sid) for sid in streams])
    dvfs = adm.apply_dvfs(batch_size)
    reqs = [dataclasses.replace(r, n_generated=0, energy_j=0.0, done=False,
                                finish_reason="")
            for r in sorted(workload, key=lambda r: (r.arrival_s, r.req_id))]
    queue: List[ReplayRequest] = []
    active: List[ReplayRequest] = []
    pending = list(reqs)
    t, shed, tokens = 0.0, 0, 0
    while (pending or queue or active) and t < timeline.t_end + step_s:
        while pending and pending[0].arrival_s <= t:
            queue.append(pending.pop(0))
        # TTL shed walk (same order + ahead accounting as the live engine:
        # decode budgets and queued prompt tokens tracked separately)
        ahead = sum(r.max_new_tokens - r.n_generated for r in active)
        ahead_prefill = 0
        for r in list(queue):
            # should_shed only reads ttl_s, so ReplayRequest passes directly
            if adm.should_shed(r, ahead, ahead_prefill):
                queue.remove(r)
                r.done, r.finish_reason = True, "shed"
                shed += 1
            else:
                ahead += r.max_new_tokens
                ahead_prefill += r.prompt_tokens
        while queue and len(active) < batch_size and \
                adm.admit(len(active), batch_size):
            active.append(queue.pop(0))
        if active:
            e = timeline.window_j(t, t + step_s) / len(active)
            n_gen = len(active) * tokens_per_step
            adm.stats.observe("decode", n_gen, step_s)
            tokens += n_gen
            for r in list(active):
                r.energy_j += e
                r.n_generated += tokens_per_step
                if r.n_generated >= r.max_new_tokens:
                    r.done, r.finish_reason = True, "length"
                    active.remove(r)
        t += step_s
    return PolicyResult(
        policy=name,
        energy_j=timeline.window_j(0.0, t),
        attributed_j=sum(r.energy_j for r in reqs),
        completed=sum(r.finish_reason == "length" for r in reqs),
        shed=shed, tokens=tokens, duration_s=t,
        per_request_j=tuple(sorted((r.req_id, r.energy_j) for r in reqs)),
        dvfs_f_ghz=dvfs.f_ghz if dvfs else None)


# ---------------------------------------------------------------------------
# cluster replay (recorded power through the resource manager)


def replay_cluster(reader: TraceReader, topo: Topology,
                   jobs: Sequence[Dict], step_s: float = 1.0,
                   idle_off_s: float = 600.0) -> Tuple[ClusterJobResult, ...]:
    """Run a job schedule through ``ClusterManager`` with each job's power
    model reading the recorded node traces (ZOH at the manager's event
    clock) — quotas and job energy debit measured joules.

    ``jobs`` rows: {user, partition, n_nodes, duration_s, submit_s}.
    """
    mgr = ClusterManager(topo, idle_off_s=idle_off_s)
    recorded = rebuild_sources(reader, "hold")      # one decode, all nodes
    fns = {name: node_power_fn(reader, name, sources=recorded)
           for name in topo.nodes if name in recorded}

    def power_model(node: str) -> float:
        fn = fns.get(node)
        return fn(mgr.elastic.t) if fn else 0.0

    t_end = max((reader.time_range(s["id"])[1] for s in reader.streams),
                default=0.0)
    schedule = sorted(jobs, key=lambda j: j.get("submit_s", 0.0))
    submitted = []
    for spec in schedule:
        t_sub = float(spec.get("submit_s", 0.0))
        if t_sub > mgr.elastic.t:
            mgr.advance(t_sub - mgr.elastic.t)
        submitted.append(mgr.submit(
            spec["user"], spec["partition"], int(spec["n_nodes"]),
            float(spec["duration_s"]), power_model))
    horizon = max(t_end, max((j.end_t for j in submitted), default=0.0))
    if horizon > mgr.elastic.t:
        mgr.advance(horizon - mgr.elastic.t + step_s)
    return tuple(ClusterJobResult(j.job_id, j.user, j.state, j.energy_j,
                                  j.start_t, j.end_t)
                 for j in submitted)


# ---------------------------------------------------------------------------
# one-call harness


def replay(path, workload: Optional[Sequence[ReplayRequest]] = None,
           policies: Optional[Dict[str, AdmissionController]] = None,
           *, batch_size: int = 4, step_s: float = 0.01,
           node: Optional[str] = None, topo: Optional[Topology] = None,
           cluster_jobs: Optional[Sequence[Dict]] = None) -> ReplayReport:
    """Load a trace and replay the given policies (and, optionally, a
    cluster job schedule) against it."""
    with TraceReader(path) as reader:
        duration = max((reader.time_range(s["id"])[1]
                        for s in reader.streams), default=0.0)
        results = []
        if workload is not None:
            streams = [s["id"] for s in reader.streams
                       if node is None or s.get("node") == node]
            timeline = EnergyTimeline([reader.read(sid) for sid in streams])
            for pname, adm in (policies or
                               {"baseline": None}).items():
                results.append(replay_policy(
                    reader, workload, adm, name=pname,
                    batch_size=batch_size, step_s=step_s, node=node,
                    timeline=timeline))
        jobs = ()
        if topo is not None and cluster_jobs:
            jobs = replay_cluster(reader, topo, cluster_jobs)
        return ReplayReport(
            trace_path=reader.path, n_streams=len(reader.streams),
            n_samples=reader.n_samples(), duration_s=duration,
            results=tuple(results), cluster_jobs=jobs)
