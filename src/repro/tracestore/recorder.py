"""Recording sessions into ``.dkt`` traces.

Two entry points:

``ClusterRecorder``        multi-node: one ``MonitorSession`` per
                           ``cluster.topology.Node`` with one probe per
                           chip (``NodeSpec.devices``), all on a shared
                           session clock; every sampling window drains each
                           node's per-probe streams into one multi-stream
                           trace file. Probe chains honor the main board's
                           I2C budget: nodes with more chips than the
                           paper's six-per-connector recommendation attach
                           oversubscribed, and each stream's *effective*
                           report rate is persisted with it.
``record_session`` /       single-node: export an existing session's
``record_engine``          accumulated blocks (e.g. a live serving run,
                           window boundaries intact) plus the engine's
                           telemetry event log, so the run can be replayed
                           deterministically offline.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Sequence

from repro.cluster.topology import Node, Topology
from repro.core.probe import REPORT_SPS, ProbeConfig
from repro.obs import events_to_meta
from repro.telemetry import MonitorSession, MutableSource
from repro.tracestore.io import TraceWriter

SourceFactory = Callable[[Node, object], object]   # (node, chip) -> PowerSource


def _idle_sources(node: Node, dev) -> MutableSource:
    """Default factory: each chip starts at its idle draw; the host updates
    it (``ClusterRecorder.set_power``) as the workload runs."""
    return MutableSource(dev.idle_w)


class ClusterRecorder:
    """Records every node of a topology into one multi-stream trace."""

    def __init__(self, topo: Topology, path,
                 nodes: Optional[Sequence[str]] = None,
                 source_factory: SourceFactory = _idle_sources,
                 grid_sps: float = REPORT_SPS, clock_t0: float = 0.0,
                 probe_cfg: Optional[ProbeConfig] = None,
                 meta: Optional[Dict] = None):
        names = list(nodes) if nodes is not None else sorted(topo.nodes)
        missing = [n for n in names if n not in topo.nodes]
        if missing:
            raise KeyError(f"nodes not in topology: {missing}")
        self.sessions: Dict[str, MonitorSession] = {}
        self.sources: Dict[str, List] = {}
        self._streams: Dict[str, Dict[int, int]] = {}   # node -> pid -> sid
        self.writer = TraceWriter(path, meta=dict(meta or {}))
        self.writer.meta.update({
            "kind": "cluster", "clock_t0": clock_t0, "grid_sps": grid_sps,
            "nodes": names,
        })
        for name in names:
            node = topo.nodes[name]
            chips = list(node.spec.devices)
            srcs = [source_factory(node, dev) for dev in chips]
            by_src = {id(s): i for i, s in enumerate(srcs)}
            # one probe per chip on the node's mesh position; chains past
            # the six-per-connector I2C recommendation degrade per-probe
            # rate instead of refusing (oversubscribe)
            sess = MonitorSession(srcs, node=name, clock_t0=clock_t0,
                                  probe_cfg=probe_cfg, grid_sps=grid_sps,
                                  oversubscribe=True)
            self.sessions[name] = sess
            self.sources[name] = srcs
            self._streams[name] = {}
            for pid, bus, src, sps, volts in sess.probe_rows():
                chip_i = by_src[id(src)]
                dev = chips[chip_i]
                sid = self.writer.add_stream(
                    f"{name}/chip{chip_i}", node=name, chip=chip_i,
                    device=dev.name, probe_id=pid, bus=bus, sps=sps,
                    volts=volts, partition=node.partition)
                self._streams[name][pid] = sid
        self._closed = False

    # -- host-side power updates --------------------------------------------

    def set_power(self, node: str, watts) -> None:
        """Update a node's chip power(s) before the next window: a scalar
        applies to every chip, a sequence maps per chip. Only meaningful
        for ``MutableSource``-backed recorders."""
        srcs = self.sources[node]
        vals = (list(watts) if isinstance(watts, (list, tuple))
                else [watts] * len(srcs))
        if len(vals) != len(srcs):
            raise ValueError(f"{node} has {len(srcs)} chips, got "
                             f"{len(vals)} powers")
        for src, w in zip(srcs, vals):
            src.set(float(w))

    # -- recording -----------------------------------------------------------

    @property
    def cursor(self) -> float:
        """Shared session clock (all node sessions advance in lock step)."""
        return next(iter(self.sessions.values())).cursor

    def sample(self, wall_s: float, tags=()) -> float:
        """Sample ``wall_s`` seconds on every node, flush each probe's
        window to its stream, and return the cluster energy of the window."""
        if self._closed:
            raise RuntimeError("ClusterRecorder is closed")
        total = 0.0
        for name, sess in self.sessions.items():
            streams = sess.sample_streams(wall_s, tags=tags)
            for blk in sess.drain():        # bound recorder memory
                total += blk.energy_j()
            if streams:
                for pid, block in streams.items():
                    self.writer.append(self._streams[name][pid], block)
        return total

    def close(self) -> str:
        if not self._closed:
            self.writer.meta["duration_s"] = self.cursor
            self._closed = True
            return self.writer.close()
        return self.writer.path

    def __enter__(self) -> "ClusterRecorder":
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# single-session export (live runs)


def record_session(session: MonitorSession, path, node: str = "node",
                   events: Optional[List] = None,
                   meta: Optional[Dict] = None) -> str:
    """Export a session's accumulated blocks to a single-stream trace.

    Each block becomes one chunk, so the session's window boundaries (one
    per ``sample()`` call) survive — ``replay_attribution`` re-drives an
    identical session window by window against the recorded power.
    ``events`` rows may be typed :class:`repro.obs.TelemetryEvent`\\ s or
    legacy flat dicts; both serialize to the same meta schema.
    """
    rows = session.probe_rows()
    _, _, _, sps, volts = rows[0]
    m = {"kind": "session", "node": node, "grid_sps": session.grid_sps,
         "events": events_to_meta(events or [])}
    m.update(meta or {})
    with TraceWriter(path, meta=m) as w:
        sid = w.add_stream(f"{node}/probe0", node=node, sps=sps, volts=volts)
        for block in session.blocks():
            w.append(sid, block)
    return os.fspath(path)


def record_engine(tel, path, node: str = "serve-node",
                  meta: Optional[Dict] = None) -> str:
    """Export a serving engine's telemetry (``EngineTelemetry``): the
    session's sample windows plus the per-window event log (phase, wall
    time, token count, slot-tag -> request ids) that deterministic replay
    needs to reproduce the live per-request attribution."""
    return record_session(tel.session, path, node=node, events=tel.events,
                          meta=meta)
