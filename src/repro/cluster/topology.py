"""Cluster topology (paper Sec. 2): partitions, nodes, network.

DALEK: four partitions x four nodes on a 2.5 GbE switch (one 5 GbE
partition), frontend with 2x10 Gbps aggregated uplinks, per-partition /27
subnets inside 192.168.1.0/24. The TPU deployment maps pods to partitions
with ICI links inside a pod and a DCN "switch" between pods — same
two-tier structure, which is why the paper's comm lessons transfer.
"""
from __future__ import annotations

import dataclasses
import ipaddress
from typing import Dict, List, Optional, Tuple

from repro.core import hw


@dataclasses.dataclass(frozen=True)
class Link:
    a: str
    b: str
    gbps: float


@dataclasses.dataclass
class Node:
    name: str
    partition: str
    spec: hw.NodeSpec
    ip: str
    switch_port: int


class Topology:
    def __init__(self):
        self.nodes: Dict[str, Node] = {}
        self.links: List[Link] = []
        self.partitions: Dict[str, List[str]] = {}

    def add_node(self, node: Node, link_gbps: float):
        self.nodes[node.name] = node
        self.partitions.setdefault(node.partition, []).append(node.name)
        self.links.append(Link(node.name, "switch", link_gbps))

    def partition_nodes(self, partition: str) -> List[str]:
        return list(self.partitions.get(partition, []))

    def bisection_gbps(self, names: List[str]) -> float:
        """Min aggregate bandwidth in/out of a node set (star topology:
        bottleneck is the sum of member uplinks vs the rest)."""
        inside = sum(l.gbps for l in self.links if l.a in names)
        outside = sum(l.gbps for l in self.links if l.a not in names
                      and l.a != "switch")
        return min(inside, outside)


def dalek_topology() -> Topology:
    """The paper's exact cluster (Tab. 3 addressing)."""
    topo = Topology()
    base = ipaddress.ip_address("192.168.1.0")
    subnet_starts = {"az4-n4090": 1, "az4-a7900": 33,
                     "iml-ia770": 65, "az5-a890m": 97}
    ports = {"az4-n4090": 33, "az4-a7900": 37, "iml-ia770": 41,
             "az5-a890m": 45}
    for pname, part in hw.DALEK_PARTITIONS.items():
        for i in range(part.n_nodes):
            ip = str(base + subnet_starts[pname] + i)
            node = Node(f"{pname}-{i}", pname, part.node, ip,
                        ports[pname] + i)
            topo.add_node(node, part.node.net_gbps)
    return topo


def tpu_topology(n_pods: int = 2, chips_per_pod: int = 256,
                 hosts_per_pod: int = 64) -> Topology:
    """TPU v5e deployment: hosts of 4 chips, ICI inside a pod, DCN across."""
    topo = Topology()
    part = hw.tpu_pod_partition()
    for p in range(n_pods):
        pname = f"pod{p}"
        for h in range(hosts_per_pod):
            node = Node(f"{pname}-host{h}", pname, part.node,
                        f"10.{p}.{h // 256}.{h % 256}", h)
            topo.add_node(node, part.node.net_gbps)
    return topo


def validate_addressing(topo: Topology) -> bool:
    """Paper List. 1: /27 blocks per partition inside one /24."""
    for pname, names in topo.partitions.items():
        ips = sorted(int(ipaddress.ip_address(topo.nodes[n].ip))
                     for n in names)
        if "pod" in pname:
            continue
        block = ips[0] >> 5
        if any((ip >> 5) != block for ip in ips):
            return False
    return True
