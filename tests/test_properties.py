"""Property-based tests on system invariants (hypothesis) + algorithmic
equivalences: chunkwise==recurrent for mLSTM/SSD, ring-cache==full-cache
sliding window, head padding==function preservation, MoE conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build_model
from repro.models.mamba2 import ssd_chunkwise, ssd_step
from repro.models.xlstm import mlstm_chunkwise, mlstm_step
from repro.parallel.sharding import spec_for


# ---------------------------------------------------------------------------
# chunkwise-parallel == step recurrence (the sub-quadratic forms are exact)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([2, 4, 8, 16]))
def test_mlstm_chunkwise_equals_recurrence(seed, chunk):
    rng = np.random.default_rng(seed)
    b, t, nh, dh = 2, 16, 2, 8
    q, k, v = (jnp.asarray(rng.normal(size=(b, t, nh, dh)), jnp.float32)
               for _ in range(3))
    logi = jnp.asarray(rng.normal(size=(b, t, nh)) - 1.0, jnp.float32)
    logf = jnp.asarray(-np.abs(rng.normal(size=(b, t, nh))), jnp.float32)
    C0 = jnp.zeros((b, nh, dh, dh))
    n0 = jnp.zeros((b, nh, dh))
    h_chunk, (C1, n1) = mlstm_chunkwise(q, k, v, logi, logf, (C0, n0),
                                        chunk=chunk)
    # sequential reference
    C, n = C0, n0
    hs = []
    for i in range(t):
        h, (C, n) = mlstm_step(q[:, i:i+1], k[:, i:i+1], v[:, i:i+1],
                               logi[:, i:i+1], logf[:, i:i+1], (C, n))
        hs.append(h)
    h_seq = jnp.concatenate(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([2, 4, 8]))
def test_ssd_chunkwise_equals_recurrence(seed, chunk):
    rng = np.random.default_rng(seed)
    b, t, nh, p, n = 2, 16, 2, 4, 6
    x = jnp.asarray(rng.normal(size=(b, t, nh, p)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, t, nh))) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(nh,))), jnp.float32)
    S0 = jnp.zeros((b, nh, n, p))
    y_chunk, S1 = ssd_chunkwise(x, bm, cm, dt, a, S0, chunk=chunk)
    S, ys = S0, []
    for i in range(t):
        y, S = ssd_step(x[:, i:i+1], bm[:, i:i+1], cm[:, i:i+1],
                        dt[:, i:i+1], a, S)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# query-head padding is function-preserving


def test_padded_heads_preserve_function():
    cfg = configs.get_smoke("deepseek-coder-33b")      # 8 heads, kv=2
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)),
        jnp.int32)}
    logits, _ = jax.jit(model.forward)(params, batch)

    # padded variant: 8 -> 12 query heads, wq/wo extended with zeros
    cfgp = cfg.replace(pad_q_heads=12)
    modelp = build_model(cfgp, q_block=8)
    paramsp, _ = modelp.init(jax.random.key(0))

    def graft(dst, src):
        """Interleave original heads per KV group; zero the padding."""
        out = jax.tree.map(lambda x: x, dst)
        lay_d, lay_s = out["layers"], src["layers"]
        kvh, g, g_pad = 2, 4, 6
        wq = jnp.zeros_like(lay_d["attn"]["wq"])
        wo = jnp.zeros_like(lay_d["attn"]["wo"])
        for grp in range(kvh):
            wq = wq.at[:, :, grp * g_pad:grp * g_pad + g].set(
                lay_s["attn"]["wq"][:, :, grp * g:(grp + 1) * g])
            wo = wo.at[:, grp * g_pad:grp * g_pad + g].set(
                lay_s["attn"]["wo"][:, grp * g:(grp + 1) * g])
        lay_d["attn"]["wq"] = wq
        lay_d["attn"]["wo"] = wo
        for k in ("wk", "wv"):
            lay_d["attn"][k] = lay_s["attn"][k]
        for k in ("norm1", "norm2"):
            lay_d[k] = lay_s[k]
        lay_d["mlp"] = lay_s["mlp"]
        for k in ("embedding", "unembed", "final_norm"):
            out[k] = src[k]
        return out

    paramsp = graft(paramsp, params)
    logitsp, _ = jax.jit(modelp.forward)(paramsp, batch)
    np.testing.assert_allclose(np.asarray(logitsp, np.float32),
                               np.asarray(logits, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# gemma3 ring cache == full-cache sliding window


def test_window_ring_decode_matches_full_forward():
    cfg = configs.get_smoke("gemma3-27b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    b, s = 2, 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    caches = model.init_cache(b, 64)
    logits_pf, caches = jax.jit(model.prefill)(
        params, {"tokens": tokens}, caches)
    # decode 4 more tokens greedily; compare each against full forward
    cur = tokens
    step = jax.jit(model.decode_step)
    for i in range(4):
        nxt = jnp.argmax(logits_pf, axis=-1).astype(jnp.int32)
        logits_d, caches = step(params, nxt, jnp.int32(s + i), caches)
        cur = jnp.concatenate([cur, nxt], axis=1)
        full, _ = jax.jit(model.forward)(params, {"tokens": cur})
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, -1], np.float32), rtol=0.15, atol=0.2)
        logits_pf = logits_d


# ---------------------------------------------------------------------------
# MoE invariants


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_moe_router_weights_normalized_and_conserved(seed):
    from repro.models.moe import moe_apply, moe_init
    from repro.models.common import ParamBuilder
    from repro.parallel.sharding import Sharder
    cfg = configs.get_smoke("deepseek-moe-16b")
    pb = ParamBuilder(jax.random.key(seed % 100))
    moe_init(pb, cfg, None)
    params = {k: (v if not isinstance(v, dict) else v)
              for k, v in pb.params.items()}
    # strip the [L] axis builder adds nothing here (L=None)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)) * 0.1, jnp.bfloat16)
    y, aux = moe_apply(x, params, cfg, Sharder(None))
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 0.99  # load-balance loss >= 1 at optimum E*sum(f*p)


def test_moe_capacity_drops_tokens_but_stays_finite():
    from repro.models.moe import moe_apply, moe_init
    from repro.models.common import ParamBuilder
    from repro.parallel.sharding import Sharder
    cfg = configs.get_smoke("deepseek-moe-16b").replace(capacity_factor=0.1)
    pb = ParamBuilder(jax.random.key(0))
    moe_init(pb, cfg, None)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, cfg.d_model)),
                    jnp.bfloat16)
    y, _ = moe_apply(x, pb.params, cfg, Sharder(None))
    assert np.isfinite(np.asarray(y, np.float32)).all()


# ---------------------------------------------------------------------------
# sharding spec properties


@settings(max_examples=30, deadline=None)
@given(d0=st.sampled_from([1, 3, 16, 48, 64]),
       d1=st.sampled_from([2, 8, 16, 256]))
def test_spec_divisibility_always_respected(d0, d1):
    import jax as _jax
    mesh = _jax.sharding.AbstractMesh(
        (2, 2), ("data", "model"),
        axis_types=(_jax.sharding.AxisType.Auto,) * 2)
    spec = spec_for(mesh, ("embed", "mlp"), (d0, d1))
    for dim, ax in zip((d0, d1), tuple(spec) + (None,) * 2):
        if ax is not None:
            size = mesh.shape[ax] if isinstance(ax, str) else int(
                np.prod([mesh.shape[a] for a in ax]))
            assert dim % size == 0


def test_spec_no_axis_reuse():
    import jax as _jax
    mesh = _jax.sharding.AbstractMesh(
        (2, 2), ("data", "model"),
        axis_types=(_jax.sharding.AxisType.Auto,) * 2)
    # both logical axes want "model": second must drop
    spec = spec_for(mesh, ("vocab", "mlp"), (16, 16))
    axes_used = [s for s in spec if s is not None]
    flat = []
    for a in axes_used:
        flat.extend(a if isinstance(a, tuple) else (a,))
    assert len(flat) == len(set(flat))
