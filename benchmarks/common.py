"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""
import time

import jax


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time per call in seconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name, seconds, derived=""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
