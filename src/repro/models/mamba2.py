"""Mamba2 (SSD) blocks + Zamba2 hybrid (Mamba2 backbone with a *shared*
attention block applied every ``cfg.attn_every`` layers, distinct KV cache per
application site) [arXiv:2411.15242].

The SSD scan uses the chunkwise-parallel algorithm (intra-chunk masked
matmuls + inter-chunk recurrent state passing) — sub-quadratic, and the
single-step recurrence used for decode agrees exactly (property-tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import ParamBuilder
from repro.models.xlstm import _causal_conv
from repro.parallel.sharding import Sharder


def mamba_init(pb: ParamBuilder, cfg: ModelConfig, L):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.head_dim
    pre, pax = (L,), ("layers",)
    proj_out = 2 * di + 2 * n + nh
    pb.dense("norm", pre + (d,), pax + ("norm",), zero=True)
    pb.dense("w_in", pre + (d, proj_out), pax + ("embed", "ssm_inner"), fan_in=d)
    pb.dense("conv", pre + (cfg.ssm_conv_width, di + 2 * n),
             pax + ("conv_width", "ssm_inner"), fan_in=cfg.ssm_conv_width)
    pb.dense("a_log", pre + (nh,), pax + (None,), zero=True)
    pb.dense("d_skip", pre + (nh,), pax + (None,), one=True)
    pb.dense("dt_bias", pre + (nh,), pax + (None,), zero=True)
    pb.dense("out_norm", pre + (di,), pax + ("ssm_inner",), zero=True)
    pb.dense("w_out", pre + (di, d), pax + ("ssm_inner", "embed"), fan_in=di)


def ssd_chunkwise(x, b_mat, c_mat, dt, a, state, chunk=256):
    """Chunkwise SSD. x: [B,T,H,P]; b_mat/c_mat: [B,T,N]; dt: [B,T,H] (>0);
    a: [H] (<0). state: [B,H,N,P] carried. Returns (y, new_state)."""
    bs, t, nh, p = x.shape
    n = b_mat.shape[-1]
    w = min(chunk, t)
    assert t % w == 0
    nc = t // w

    def rs(v):
        return v.reshape(bs, nc, w, *v.shape[2:]).swapaxes(0, 1)

    xs, bs_, cs, dts = rs(x), rs(b_mat), rs(c_mat), rs(dt)

    def body(carry, inp):
        S = carry                                          # [B,H,N,P] fp32
        xc, bc, cc, dtc = inp
        xf = xc.astype(jnp.float32)
        bf, cf = bc.astype(jnp.float32), cc.astype(jnp.float32)
        logf = dtc * a                                     # [B,W,H] <= 0
        lc = jnp.cumsum(logf, axis=1)
        ltot = lc[:, -1]                                   # [B,H]
        # intra-chunk
        dm = lc[:, :, None, :] - lc[:, None, :, :]         # [B,W,W,H]
        mask = jnp.tril(jnp.ones((w, w), bool))
        A = jnp.where(mask[None, :, :, None], jnp.exp(dm), 0.0)
        cb = jnp.einsum("btn,bsn->bts", cf, bf)            # [B,W,W]
        scores = cb[..., None] * A * dtc[:, None, :, :]    # [B,W,W,H]
        y_intra = jnp.einsum("btsh,bshp->bthp", scores, xf)
        # inter-chunk
        y_inter = jnp.einsum("btn,bhnp->bthp", cf, S) * jnp.exp(lc)[..., None]
        # state update
        sdecay = jnp.exp(ltot[:, None] - lc) * dtc         # [B,W,H]
        S = jnp.exp(ltot)[..., None, None] * S + jnp.einsum(
            "bsn,bshp,bsh->bhnp", bf, xf, sdecay)
        return S, y_intra + y_inter

    S, ys = lax.scan(body, state, (xs, bs_, cs, dts))
    y = ys.swapaxes(0, 1).reshape(bs, t, nh, p)
    return y, S


def ssd_step(x, b_mat, c_mat, dt, a, state):
    """Single-step recurrence. x: [B,1,H,P]; b/c: [B,1,N]; dt: [B,1,H]."""
    S = state
    xf = x[:, 0].astype(jnp.float32)                       # [B,H,P]
    bf, cf = b_mat[:, 0].astype(jnp.float32), c_mat[:, 0].astype(jnp.float32)
    dtc = dt[:, 0]                                         # [B,H]
    decay = jnp.exp(dtc * a)                               # [B,H]
    S = decay[..., None, None] * S + jnp.einsum(
        "bn,bhp,bh->bhnp", bf, xf, dtc)
    y = jnp.einsum("bn,bhnp->bhp", cf, S)
    return y[:, None], S


def mamba_block(x, p, cfg: ModelConfig, shd: Sharder, state, *, chunk=256):
    """state: (S [B,H,N,P], conv_state) or None."""
    b, t, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.head_dim
    pdim = cfg.head_dim
    y = common.rms_norm(x, p["norm"])
    proj = jnp.einsum("btd,de->bte", y, p["w_in"].astype(y.dtype))
    proj = shd(proj, "batch", "seq", "act_heads")
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt_raw = proj[..., di + di + 2 * n:]                   # [B,T,H]
    if state is None:
        S = jnp.zeros((b, nh, n, pdim), jnp.float32)
        conv_state = None
    else:
        S, conv_state = state
    xbc, new_conv = _causal_conv(xbc, p["conv"], conv_state)
    xbc = jax.nn.silu(xbc)
    xssm = xbc[..., :di].reshape(b, t, nh, pdim)
    b_mat = xbc[..., di:di + n]
    c_mat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # [H] < 0
    if t == 1 and state is not None:
        ys, S = ssd_step(xssm, b_mat, c_mat, dt, a, S)
    else:
        ys, S = ssd_chunkwise(xssm, b_mat, c_mat, dt, a, S,
                              chunk=min(chunk, t))
    ys = ys + p["d_skip"].astype(jnp.float32)[:, None] * xssm.astype(jnp.float32)
    h = ys.reshape(b, t, di).astype(x.dtype)
    h = common.rms_norm(h, p["out_norm"])
    h = h * jax.nn.silu(z)
    out = jnp.einsum("btc,cd->btd", h, p["w_out"].astype(h.dtype))
    out = shd(out, "batch", "seq", "act_embed")
    new_state = None if state is None else (S, new_conv)
    return x + out, new_state


class Zamba2:
    """Mamba2 stack with one shared attention+MLP block every ``attn_every``
    layers. KV caches are sequence-sharded for long-context decode (SP)."""

    def __init__(self, cfg: ModelConfig, mesh=None, *, chunk=256, remat=True,
                 attn_impl="blocked", q_block=512, shd_rules=None,
                 barrier=False):
        self.cfg = cfg
        self.shd = Sharder(mesh, rules=shd_rules, barrier=barrier)
        self.chunk = chunk
        self.remat = remat
        self.attn_impl = attn_impl
        self.q_block = q_block
        every = cfg.attn_every or (cfg.num_layers + 1)
        self.attn_sites = [i for i in range(cfg.num_layers)
                           if (i + 1) % every == 0]
        self.groups = []
        start = 0
        for si in self.attn_sites + [cfg.num_layers]:
            self.groups.append(si - start)
            start = si + 1
        self.n_mamba = cfg.num_layers - len(self.attn_sites)

    def init(self, key):
        cfg = self.cfg
        pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
        common.embed_init(pb, cfg)
        mb = pb.child("mamba")
        mamba_init(mb, cfg, self.n_mamba)
        sb = pb.child("shared_attn")      # ONE block, shared across sites
        sb.dense("norm1", (cfg.d_model,), ("norm",), zero=True)
        sb.dense("norm2", (cfg.d_model,), ("norm",), zero=True)
        ab = sb.child("attn")
        common.attn_init(ab, cfg)
        fb = sb.child("mlp")
        common.mlp_init(fb, cfg.d_model, cfg.d_ff)
        return pb.build()

    def _shared_attn(self, x, p, positions, cache, cache_pos):
        cfg, shd = self.cfg, self.shd
        h, nc = common.attention(
            common.rms_norm(x, p["norm1"]), p["attn"], cfg, shd,
            positions=positions, impl=self.attn_impl, q_block=self.q_block,
            kv_cache=cache, cache_pos=cache_pos)
        x = x + h
        x = x + common.mlp(common.rms_norm(x, p["norm2"]), p["mlp"], shd)
        return x, nc

    def _stack(self, x, params, states, *, positions, cache_pos=None):
        cfg, shd = self.cfg, self.shd
        new_states = {} if states is not None else None
        m_off = 0

        def mbody(carry, inp):
            xc = carry
            if states is None:
                p, st = inp, None
            else:
                p, st = inp
            xc, nst = mamba_block(xc, p, cfg, shd, st, chunk=self.chunk)
            return xc, nst

        if self.remat:
            mbody = jax.checkpoint(
                mbody, policy=jax.checkpoint_policies.nothing_saveable)

        for gi, g_count in enumerate(self.groups):
            if g_count:
                gp = jax.tree.map(
                    lambda v: lax.dynamic_slice_in_dim(v, m_off, g_count, 0),
                    params["mamba"])
                if states is None:
                    x, _ = lax.scan(mbody, x, gp)
                else:
                    gst = jax.tree.map(
                        lambda v: lax.dynamic_slice_in_dim(v, m_off, g_count, 0),
                        states["mamba"])
                    x, nst = lax.scan(mbody, x, (gp, gst))
                    new_states.setdefault("_m", []).append(nst)
                m_off += g_count
            if gi < len(self.attn_sites):
                cache = None if states is None else states[f"attn_{gi}"]
                x, nc = self._shared_attn(x, params["shared_attn"], positions,
                                          cache, cache_pos)
                if states is not None:
                    new_states[f"attn_{gi}"] = nc
        if states is not None:
            parts = new_states.pop("_m")
            new_states["mamba"] = jax.tree.map(
                lambda *vs: jnp.concatenate(vs, axis=0), *parts)
        return x, new_states

    def forward(self, params, batch):
        dtype = jnp.dtype(self.cfg.dtype)
        x = common.embed(batch["tokens"], params, dtype)
        x = self.shd(x, "batch", "seq", "act_embed")
        positions = jnp.arange(x.shape[1])
        x, _ = self._stack(x, params, None, positions=positions)
        return common.unembed(x, params, self.shd), 0.0

    def init_cache(self, batch_size, max_seq, dtype=jnp.bfloat16):
        cfg = self.cfg
        di = cfg.ssm_expand * cfg.d_model
        n = cfg.ssm_state
        nh = di // cfg.head_dim
        cw = cfg.ssm_conv_width
        lm = self.n_mamba
        st = {
            "mamba": (
                jnp.zeros((lm, batch_size, nh, n, cfg.head_dim), jnp.float32),
                jnp.zeros((lm, batch_size, cw - 1, di + 2 * n), jnp.float32),
            )
        }
        for i in range(len(self.attn_sites)):
            shape = (batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim)
            st[f"attn_{i}"] = (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
        return st

    def cache_axes(self):
        st = {
            "mamba": (
                ("layers", "batch", "act_heads", None, None),
                ("layers", "batch", None, "ssm_inner"),
            )
        }
        for i in range(len(self.attn_sites)):
            ax = ("batch", "kv_seq", "act_kv_heads", None)
            st[f"attn_{i}"] = (ax, ax)
        return st

    def prefill(self, params, batch, states, start_pos=None):
        """Prefill a chunk at absolute positions [start, start+S).

        Mamba/conv state in ``states`` carries left-to-right across chunks
        (the conv left-pad and SSD state resume by construction);
        ``start_pos`` offsets the shared-attention KV writes and RoPE so a
        prompt can be fed in pow2 chunks without retracing per length."""
        dtype = jnp.dtype(self.cfg.dtype)
        x = common.embed(batch["tokens"], params, dtype)
        x = self.shd(x, "batch", "seq", "act_embed")
        offset = jnp.int32(0) if start_pos is None else start_pos
        positions = jnp.arange(x.shape[1]) + offset
        x, states = self._stack(x, params, states, positions=positions,
                                cache_pos=offset)
        return common.unembed(x[:, -1:], params, self.shd), states

    def decode_step(self, params, token, pos, states):
        """One decode step. pos: scalar int32 or [B] int32 (continuous
        batching: each row decodes at its own attention position)."""
        dtype = jnp.dtype(self.cfg.dtype)
        x = common.embed(token, params, dtype)
        x = self.shd(x, "batch", "seq", "act_embed")
        if jnp.ndim(pos) == 0:
            positions = jnp.array([0], jnp.int32) + pos
        else:
            positions = pos.astype(jnp.int32)[:, None]   # [B, 1]
        x, states = self._stack(x, params, states, positions=positions,
                                cache_pos=pos)
        return common.unembed(x, params, self.shd), states
