"""dalek-lint self-tests: per-rule fixtures (positive / suppressed / clean),
baseline round-trip, CLI exit codes, and the repo-is-clean invariant."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (ProjectIndex, analyze_paths, analyze_project,
                            analyze_source, rule_codes)
from repro.analysis import baseline as baseline_mod
from repro.analysis.cli import gate_rows
from repro.analysis.cli import main as cli_main

REPO = Path(__file__).resolve().parent.parent


def lint(code, path="mod.py", **kw):
    return analyze_source(textwrap.dedent(code), path, **kw)


def active(findings, code=None):
    return [f for f in findings if f.active
            and (code is None or f.code == code)]


def codes(findings):
    return sorted({f.code for f in findings if f.active})


# -- DLK001 bare-jit ---------------------------------------------------------


def test_bare_jit_call_and_decorator_flagged():
    fs = lint("""
        import jax, functools
        step = jax.jit(lambda x: x)

        @jax.jit
        def f(x):
            return x

        @functools.partial(jax.jit, static_argnums=(1,))
        def g(x, n):
            return x
    """)
    assert len(active(fs, "DLK001")) == 3


def test_bare_jit_from_import_alias():
    fs = lint("""
        from jax import jit
        f = jit(lambda x: x)
    """)
    assert codes(fs) == ["DLK001"]


def test_counting_jit_clean():
    fs = lint("""
        from repro.core.tracing import counting_jit
        def step(x):
            return x
        f = counting_jit(step, "step")
    """)
    assert active(fs) == []


def test_bare_jit_suppressed_and_skips_tests():
    src = """
        import jax
        f = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture
    """
    fs = lint(src)
    assert active(fs) == [] and any(f.suppressed for f in fs)
    assert active(lint("""
        import jax
        f = jax.jit(lambda x: x)
    """, path="tests/test_x.py")) == []


# -- DLK002 host-sync-in-hot-loop --------------------------------------------

HOT_LOOP = """
    import jax
    import numpy as np
    step = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture

    def drive(x):
        for _ in range(8):
            x = step(x)
            {sync}
        return x
"""


@pytest.mark.parametrize("sync", [
    "h = np.asarray(x)", "h = x.item()", "h = int(x)",
    "h = float(np.asarray(x)[0])", "x.block_until_ready()",
])
def test_host_sync_in_loop_flagged(sync):
    assert codes(lint(HOT_LOOP.format(sync=sync))) == ["DLK002"]


def test_host_sync_on_host_value_clean():
    # np.asarray on a host-side value (the prompt) is not a device sync
    fs = lint("""
        import jax
        import numpy as np
        step = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture

        def drive(reqs):
            for r in reqs:
                p = np.asarray(r)
                y = step(p)
            return y
    """)
    assert active(fs, "DLK002") == []


def test_host_sync_outside_loop_clean():
    fs = lint("""
        import jax
        import numpy as np
        step = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture

        def drive(x):
            y = step(x)
            return np.asarray(y)
    """)
    assert active(fs, "DLK002") == []


def test_host_sync_suppressed():
    fs = lint(HOT_LOOP.format(
        sync="h = np.asarray(x)  # dalek: allow[host-sync] designed fetch"))
    assert active(fs) == [] and any(
        f.suppressed and f.code == "DLK002" for f in fs)


# -- DLK003 traced-value-branch ----------------------------------------------


def test_traced_branch_flagged():
    fs = lint("""
        import jax

        @jax.jit  # dalek: allow[bare-jit] fixture
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert "DLK003" in codes(fs)


def test_traced_branch_via_factory_and_name_arg():
    fs = lint("""
        import jax
        from repro.core.tracing import counting_jit

        def make_step(scale):
            def step(x):
                while x < scale:
                    x = x * 2
                return x
            return step

        def body(x):
            assert x > 0
            return x
        g = counting_jit(body, "body")
    """)
    assert len(active(fs, "DLK003")) == 2


def test_traced_branch_static_and_safe_tests_clean():
    fs = lint("""
        import jax, functools

        @functools.partial(jax.jit, static_argnames=("n",))  # dalek: allow[bare-jit] fixture
        def f(x, n, key=None):
            if n > 2:                      # static: fine
                x = x + 1
            if key is None:                # identity test: fine
                x = x + 2
            if x.ndim == 2:                # shape introspection: fine
                x = x + 3
            return x
    """)
    assert active(fs) == []


# -- DLK004 jit-kwargs-hygiene -----------------------------------------------


def test_jit_kwargs_overlap_and_range():
    fs = lint("""
        import jax
        def f(a, b):
            return a + b
        g = jax.jit(f, static_argnums=(1,), donate_argnums=(1,))  # dalek: allow[bare-jit] fixture
        h = jax.jit(f, donate_argnums=(5,))  # dalek: allow[bare-jit] fixture
    """)
    msgs = [f.message for f in active(fs, "DLK004")]
    assert any("both static and donated" in m for m in msgs)
    assert any("out of range" in m for m in msgs)


def test_jit_kwargs_unknown_argname_and_array_static():
    fs = lint("""
        import jax
        def f(x, n):
            return x * n
        g = jax.jit(f, static_argnames=("m",))  # dalek: allow[bare-jit] fixture

        def h(x, w):
            return x @ w.T
        k = jax.jit(h, static_argnames=("w",))  # dalek: allow[bare-jit] fixture
    """)
    msgs = [f.message for f in active(fs, "DLK004")]
    assert any("not a parameter" in m for m in msgs)
    assert any("used like an array" in m for m in msgs)


def test_jit_kwargs_use_after_donate():
    fs = lint("""
        import jax
        def f(state, batch):
            return state
        step = jax.jit(f, donate_argnums=(0,))  # dalek: allow[bare-jit] fixture

        def drive(state, batch):
            out = step(state, batch)
            return state.params        # donated buffer read again
    """)
    assert any("use-after-donate" in f.message for f in active(fs, "DLK004"))


def test_jit_kwargs_clean():
    fs = lint("""
        import jax
        def f(state, batch, n):
            return state
        step = jax.jit(f, static_argnums=(2,), donate_argnums=(0,))  # dalek: allow[bare-jit] fixture

        def drive(state, batch):
            state = step(state, batch, 4)
            return state
    """)
    assert active(fs, "DLK004") == []


# -- DLK005 untagged-energy-region -------------------------------------------


def test_untagged_sample_flagged():
    fs = lint("""
        from repro.telemetry.session import MonitorSession
        session = MonitorSession(None)
        session.sample(0.1)
    """)
    assert codes(fs) == ["DLK005"]


def test_sample_with_tags_or_region_clean():
    fs = lint("""
        from repro.telemetry.session import MonitorSession
        session = MonitorSession(None)
        session.sample(0.1, tags=("prefill",))
        with session.region("train_step"):
            session.sample(0.2)
    """)
    assert active(fs) == []


def test_untagged_sample_factory_unpack_and_suppression():
    fs = lint("""
        from repro.train.loop import make_session
        session, power = make_session()
        session.sample(0.1)  # dalek: allow[untagged-energy] fixture
        session.sample(0.2)
    """)
    act = active(fs, "DLK005")
    assert len(act) == 1 and act[0].line == 5
    assert any(f.suppressed for f in fs)


# -- DLK006 refcount-pairing --------------------------------------------------


def test_refcount_discarded_and_unused_alloc_flagged():
    fs = lint("""
        def a(pool):
            pool.alloc()               # result dropped

        def b(pool):
            blk = pool.alloc()         # never used again
            return None
    """)
    msgs = [f.message for f in active(fs, "DLK006")]
    assert any("discarded" in m for m in msgs)
    assert any("never used" in m for m in msgs)


def test_refcount_early_exit_flagged_guard_exempt():
    fs = lint("""
        def leaky(self, pool, full):
            blk = pool.alloc()
            if full:
                return None            # leaks blk
            self.table.append(blk)

        def guarded(self, pool):
            blk = pool.alloc()
            if blk is None:
                return None            # alloc failed: nothing to release
            self.table.append(blk)
    """)
    act = active(fs, "DLK006")
    assert len(act) == 1 and "leaks on this path" in act[0].message


def test_refcount_clean_patterns():
    fs = lint("""
        def map_shared(self, slot, blocks):
            for j, blk in enumerate(blocks):
                self.pool.retain(blk)
                self.tables[slot, j] = blk

        def grow(self, slot):
            blk = self.pages.alloc()
            if blk is None:
                return False
            self.tables[slot].append(blk)
            return True
    """)
    assert active(fs, "DLK006") == []


# -- DLK007 unclosed-span ------------------------------------------------------


def test_span_discarded_and_unclosed_flagged():
    fs = lint("""
        def a(tracer):
            tracer.span("prefill")             # result dropped

        def b(tracer):
            sp = tracer.begin("queued")        # never ended here
            sp.set("x", 1)

        def c(self):
            self.tracer.begin("decode")        # result dropped
    """)
    act = active(fs, "DLK007")
    assert len(act) == 3
    msgs = [f.message for f in act]
    assert sum("discarded" in m for m in msgs) == 2
    assert any("'sp'" in m and "unclosed span" in m for m in msgs)


def test_span_name_scope_is_per_function():
    # an .end() in a DIFFERENT function must not excuse b()'s handle
    fs = lint("""
        def b(tracer):
            h = tracer.begin("queued")

        def elsewhere(h):
            h.end()
    """)
    assert len(active(fs, "DLK007")) == 1


def test_span_clean_patterns():
    fs = lint("""
        import contextlib

        def w(tracer, x):
            with tracer.span("prefill", bucket=8) as sp:
                sp.set("window", 3)
            return x

        def guarded(tracer):
            cm = (tracer.span("step") if tracer is not None
                  else contextlib.nullcontext())
            with cm as sp:
                pass

        def handle(tracer):
            sp = tracer.begin("queued")
            sp.update(shed=True)
            sp.end()

        class Engine:
            def submit(self, req):
                # ownership transferred into the map: another method closes
                self._req_spans[req.req_id] = self.tracer.begin("queued")

            def open(self):
                self._sp = self.tracer.begin("epoch")

            def close(self):
                self._sp.end()

        def transfer(tracer):
            return tracer.begin("handed-off")
    """)
    assert active(fs, "DLK007") == []


def test_span_attr_handle_without_end_flagged_and_suppression():
    fs = lint("""
        class Engine:
            def open(self):
                self._sp = self.tracer.begin("epoch")   # no .end anywhere
    """)
    act = active(fs, "DLK007")
    assert len(act) == 1 and "self._sp" in act[0].message
    fs = lint("""
        def a(tracer):
            tracer.span("x")  # dalek: allow[unclosed-span] fixture
    """)
    assert active(fs) == [] and any(
        f.suppressed and f.code == "DLK007" for f in fs)
    # rule skips test files (they open dangling spans to probe the tracer)
    assert active(lint("""
        def a(tracer):
            tracer.span("x")
    """, path="tests/test_x.py")) == []


# -- DLK008 state-reset-pairing ------------------------------------------------


def test_state_release_without_reset_flagged():
    fs = lint("""
        class Engine:
            def finish(self, slot):
                self.slots.release(slot)
    """)
    act = active(fs, "DLK008")
    assert len(act) == 1 and "self.slots.release" in act[0].message
    # bare (non-self) slot-manager receiver fires too
    fs = lint("""
        def finish(slots, slot):
            slots.release(slot)
    """)
    assert len(active(fs, "DLK008")) == 1


def test_state_release_paired_with_reset_clean():
    # each adapter-side scrub verb satisfies the pairing
    for verb in ("free_slot", "release_slot", "reset_cache_slot", "free"):
        fs = lint(f"""
            class Engine:
                def finish(self, slot):
                    self.adapter.{verb}(slot.index)
                    self.slots.release(slot)
        """)
        assert active(fs, "DLK008") == [], verb


def test_state_release_exemptions_and_suppression():
    # the manager's own release() resets its own bookkeeping — exempt,
    # and non-slot receivers (elastic pools, locks) never match
    fs = lint("""
        class SlotManager:
            def release(self, slot):
                slot.req = None

        def drain(self, job):
            self.elastic.release(job.nodes)
    """)
    assert active(fs, "DLK008") == []
    fs = lint("""
        def finish(slots, slot):
            slots.release(slot)  # dalek: allow[state-reset-pairing] fixture
    """)
    assert active(fs) == [] and any(
        f.suppressed and f.code == "DLK008" for f in fs)


def test_checked_in_baseline_has_no_state_reset_pairing():
    # DLK008 mirrors DLK001 policy: fixed, never grandfathered
    keys = baseline_mod.load()
    assert not any(code == "DLK008" for code, _, _ in keys)


# -- DLK009 interproc-host-sync ------------------------------------------------

_SYNC_HELPER_MOD = """
    import jax
    import numpy as np

    step = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture

    def fetch(val):
        return np.asarray(val)

    def drive(xs):
        out = []
        for x in xs:
            y = step(x)
            out.append(fetch(y))
        return out
"""


def test_interproc_sync_same_module_flagged():
    fs = lint(_SYNC_HELPER_MOD)
    act = active(fs, "DLK009")
    assert len(act) == 1
    assert "fetch" in act[0].message and "syncs" in act[0].message


def test_interproc_sync_cross_module_flagged(tmp_path):
    # the ISSUE acceptance case: the sync is only reachable through a
    # helper defined in ANOTHER module — DLK002 is structurally blind here
    (tmp_path / "helpers.py").write_text(textwrap.dedent("""
        import numpy as np

        def fetch(val):
            return np.asarray(val)
    """))
    (tmp_path / "engine.py").write_text(textwrap.dedent("""
        import jax
        from helpers import fetch

        step = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture

        def drive(xs):
            out = []
            for x in xs:
                y = step(x)
                out.append(fetch(y))
            return out
    """))
    fs = analyze_project([str(tmp_path)])
    act = active(fs, "DLK009")
    assert len(act) == 1 and act[0].path.endswith("engine.py")
    assert "fetch" in act[0].message


def test_interproc_sync_transitive_and_suppressed(tmp_path):
    # taint crosses TWO call hops: fetch() -> as_host(); and the pragma at
    # the call site suppresses
    (tmp_path / "deep.py").write_text(textwrap.dedent("""
        import jax
        import numpy as np

        step = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture

        def as_host(v):
            return np.asarray(v)

        def fetch(val):
            return as_host(val)

        def drive(xs):
            for x in xs:
                y = step(x)
                z = fetch(y)  # dalek: allow[interproc-host-sync] fixture
        """))
    fs = analyze_project([str(tmp_path)])
    assert active(fs, "DLK009") == []
    assert any(f.code == "DLK009" and f.suppressed for f in fs)
    # without the pragma the transitive chain is flagged
    src = (tmp_path / "deep.py").read_text().replace(
        "  # dalek: allow[interproc-host-sync] fixture", "")
    (tmp_path / "deep.py").write_text(src)
    assert len(active(analyze_project([str(tmp_path)]), "DLK009")) == 1


def test_interproc_sync_clean_cases():
    # helper does not sync -> clean
    fs = lint("""
        import jax

        step = jax.jit(lambda x: x)  # dalek: allow[bare-jit] fixture

        def keep(val):
            return val

        def drive(xs):
            for x in xs:
                y = step(x)
                z = keep(y)
    """)
    assert active(fs, "DLK009") == []
    # helper syncs, but the argument is not a device value -> clean
    fs = lint("""
        import numpy as np

        def fetch(val):
            return np.asarray(val)

        def drive(xs):
            for x in xs:
                z = fetch(x)
    """)
    assert active(fs, "DLK009") == []


def test_checked_in_baseline_has_no_interproc_sync():
    # DLK009 mirrors DLK001 policy: fixed, never grandfathered
    keys = baseline_mod.load()
    assert not any(code == "DLK009" for code, _, _ in keys)


# -- DLK010 dtype-drift --------------------------------------------------------

# the pre-PR-9 xlstm._causal_conv bug, verbatim shape: the carry comes back
# as a slice of the activation-dtype concat — one decode retrace per family
_PRE_PR9_CONV = """
    import jax.numpy as jnp

    def causal_conv(x, w, state=None):
        width = w.shape[0]
        if state is None:
            xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
        else:
            xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        out = xp * w
        new_state = xp[:, -(width - 1):]
        return out, new_state
"""


def test_dtype_drift_flags_pre_pr9_conv_carry():
    fs = lint(_PRE_PR9_CONV)
    act = active(fs, "DLK010")
    assert len(act) == 1 and "retraces" in act[0].message


def test_dtype_drift_clean_when_pinned():
    # the PR 9 fix: pin the carry back to its own dtype before returning
    fs = lint("""
        import jax.numpy as jnp

        def causal_conv(x, w, state=None):
            width = w.shape[0]
            if state is None:
                xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
            else:
                xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
            out = xp * w
            new_state = xp[:, -(width - 1):]
            if state is not None:
                new_state = new_state.astype(state.dtype)
            return out, new_state
    """)
    assert active(fs, "DLK010") == []


def test_dtype_drift_literal_cast_and_no_carry_clean():
    # an explicit concrete dtype is a deliberate pin, not a drift
    fs = lint("""
        import jax.numpy as jnp

        def scan_step(carry, x):
            new = carry * 0.5 + x
            return new.astype(jnp.float32), x
    """)
    assert active(fs, "DLK010") == []
    # no carry-shaped params -> the lattice never runs
    fs = lint("""
        def project(x, w):
            return (x @ w).astype(x.dtype)
    """)
    assert active(fs, "DLK010") == []


def test_dtype_drift_suppressed():
    src = _PRE_PR9_CONV.replace(
        "return out, new_state",
        "return out, new_state  # dalek: allow[dtype-drift] fixture")
    fs = lint(src)
    assert active(fs, "DLK010") == []
    assert any(f.code == "DLK010" and f.suppressed for f in fs)


def test_checked_in_baseline_has_no_dtype_drift():
    # DLK010 mirrors DLK001 policy: fixed, never grandfathered
    keys = baseline_mod.load()
    assert not any(code == "DLK010" for code, _, _ in keys)


# -- DLK011 ownership-handoff --------------------------------------------------


def test_ownership_handoff_flagged():
    fs = lint("""
        def peek(blk):
            print(blk.idx)

        def run(pool):
            blk = pool.alloc()
            peek(blk)
    """)
    act = active(fs, "DLK011")
    assert len(act) == 1
    assert "peek" in act[0].message and "block" in act[0].message


def test_ownership_handoff_cross_module(tmp_path):
    (tmp_path / "inspect_util.py").write_text(textwrap.dedent("""
        def peek(blk):
            print(blk.idx)
    """))
    (tmp_path / "runner.py").write_text(textwrap.dedent("""
        from inspect_util import peek

        def run(pool):
            blk = pool.alloc()
            peek(blk)
    """))
    fs = analyze_project([str(tmp_path)])
    act = active(fs, "DLK011")
    assert len(act) == 1 and act[0].path.endswith("runner.py")


def test_ownership_handoff_clean_when_callee_consumes():
    # freeing, storing, returning, or entering in the callee settles it
    for body in ("blk.free()", "self.blocks[0] = blk", "return blk"):
        fs = lint(f"""
            class Holder:
                def sink(self, blk):
                    {body}

                def run(self, pool):
                    blk = pool.alloc()
                    self.sink(blk)
        """)
        assert active(fs, "DLK011") == [], body
    # a local consuming use (pool.free is unresolvable -> transfer) wins
    fs = lint("""
        def peek(blk):
            print(blk.idx)

        def run(pool):
            blk = pool.alloc()
            peek(blk)
            pool.free(blk)
    """)
    assert active(fs, "DLK011") == []


def test_ownership_handoff_span_and_suppression():
    fs = lint("""
        def annotate(sp):
            sp.args["x"] = 1

        def run(tracer):
            sp = tracer.begin("step")
            annotate(sp)
    """)
    assert len(active(fs, "DLK011")) == 1
    fs = lint("""
        def peek(blk):
            print(blk.idx)

        def run(pool):
            blk = pool.alloc()
            peek(blk)  # dalek: allow[ownership-handoff] fixture
    """)
    assert active(fs, "DLK011") == []
    assert any(f.code == "DLK011" and f.suppressed for f in fs)


# -- DLK012 unguarded-shared-state ---------------------------------------------


def test_unguarded_shared_state_flagged():
    fs = lint("""
        import threading

        class Stats:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0

            def inc(self):
                with self._lock:
                    self._n += 1

            def read(self):
                return self._n
    """)
    act = active(fs, "DLK012")
    assert len(act) == 1
    assert "_n" in act[0].message and "read" in act[0].message


def test_unguarded_shared_state_container_mutation_flagged():
    # writes through the container (append / item-store) count as writes
    fs = lint("""
        import threading

        class Ring:
            def __init__(self):
                self._lock = threading.Lock()
                self._events = []

            def push(self, e):
                with self._lock:
                    self._events.append(e)

            def drain(self):
                return list(self._events)
    """)
    assert len(active(fs, "DLK012")) == 1


def test_unguarded_shared_state_base_class_lock():
    # the lock is created in a base class: usage-based detection
    # (`with self._lock`) still marks the subclass as lock-guarded
    fs = lint("""
        class Counter(Metric):
            def inc(self):
                with self._lock:
                    self._values["x"] = 1

            def value(self):
                return self._values.get("x")
    """)
    assert len(active(fs, "DLK012")) == 1


def test_unguarded_shared_state_clean_cases():
    # everything guarded -> clean; init-only writes -> clean
    fs = lint("""
        import threading

        class Stats:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0
                self.edges = [1, 2, 3]

            def inc(self):
                with self._lock:
                    self._n += 1

            def read(self):
                with self._lock:
                    return self._n

            def bucket(self, v):
                return self.edges.index(v)
    """)
    assert active(fs, "DLK012") == []
    # a class without a lock is out of scope
    fs = lint("""
        class Plain:
            def __init__(self):
                self._n = 0

            def inc(self):
                self._n += 1
    """)
    assert active(fs, "DLK012") == []


def test_unguarded_shared_state_guarded_method_fixpoint():
    # `_locked`-suffix methods, and methods whose every call site holds the
    # lock, are guaranteed-guarded (the TagBus._alloc pattern)
    fs = lint("""
        import threading

        class Bus:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0
                self._slots = {}

            def _compile_locked(self):
                self._slots["a"] = 1

            def _bump(self):
                self._n += 1

            def inc(self):
                with self._lock:
                    self._bump()
                    self._compile_locked()
    """)
    assert active(fs, "DLK012") == []


def test_unguarded_shared_state_suppressed():
    fs = lint("""
        import threading

        class Stats:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0

            def inc(self):
                with self._lock:
                    self._n += 1

            def read(self):
                return self._n  # dalek: allow[unguarded-shared-state] demo
    """)
    assert active(fs, "DLK012") == []
    assert any(f.code == "DLK012" and f.suppressed for f in fs)


# -- multi-line pragma spans ---------------------------------------------------


def test_pragma_on_any_line_of_wrapped_statement():
    # regression: the pragma used to match only the node's FIRST line, so a
    # finding on a wrapped call could not be suppressed at its closing paren
    fs = lint("""
        import jax
        f = jax.jit(
            lambda x: x)  # dalek: allow[bare-jit] wrapped fixture
    """)
    assert active(fs) == [] and any(f.suppressed for f in fs)


def test_pragma_inside_statement_body_does_not_blanket_suppress():
    # a finding on an `if` (traced-branch) spans only the HEADER lines —
    # an allow[] buried in the body must not suppress it
    fs = lint("""
        import jax

        @jax.jit  # dalek: allow[bare-jit] fixture
        def f(x):
            y = x.sum()
            if y > 0:
                z = 1  # dalek: allow[traced-branch] must not reach the if
            return y
    """)
    assert len(active(fs, "DLK003")) == 1
    # on the header line itself, it does suppress
    fs = lint("""
        import jax

        @jax.jit  # dalek: allow[bare-jit] fixture
        def f(x):
            y = x.sum()
            if y > 0:  # dalek: allow[traced-branch] fixture
                z = 1
            return y
    """)
    assert active(fs, "DLK003") == []


# -- ProjectIndex --------------------------------------------------------------


def test_project_index_resolves_imports_and_methods(tmp_path):
    (tmp_path / "util.py").write_text(textwrap.dedent("""
        import numpy as np

        def pull(v):
            return np.asarray(v)

        class Sink:
            def drain(self, v):
                return v.item()
    """))
    (tmp_path / "main.py").write_text(textwrap.dedent("""
        from util import pull, Sink
        import util

        def a(v):
            return pull(v)

        def b(v):
            return util.pull(v)
    """))
    index, errors = ProjectIndex.from_paths([str(tmp_path)])
    assert errors == []
    # summaries: pull() syncs its param; a/b inherit transitively
    by_suffix = {fq.rsplit(".", 1)[-1]: s for fq, s in index.summaries.items()}
    assert 0 in by_suffix["pull"].syncs_params
    assert 0 in by_suffix["a"].syncs_params
    assert 0 in by_suffix["b"].syncs_params
    # the method is addressable too (self param offset applies at call sites)
    assert 1 in by_suffix["drain"].syncs_params


def test_project_index_order_independent(tmp_path):
    files = []
    for name in ("aa", "bb", "cc"):
        p = tmp_path / f"{name}.py"
        p.write_text(textwrap.dedent(f"""
            import numpy as np

            def sync_{name}(v):
                return np.asarray(v)
        """))
        files.append(str(p))
    fwd, _ = ProjectIndex.from_paths(files)
    rev, _ = ProjectIndex.from_paths(list(reversed(files)))
    assert [c.path for c in fwd.contexts] == [c.path for c in rev.contexts]
    assert {fq: s.facts() for fq, s in fwd.summaries.items()} \
        == {fq: s.facts() for fq, s in rev.summaries.items()}


def test_project_output_deterministic_under_shuffle(tmp_path, capsys):
    # shuffled discovery order -> byte-identical --json and --gate-json
    (tmp_path / "one.py").write_text(
        "import jax\nf = jax.jit(lambda x: x)\n")
    (tmp_path / "two.py").write_text(
        "import numpy as np\n\ndef fetch(v):\n    return np.asarray(v)\n")
    (tmp_path / "three.py").write_text("x = 1\n")
    names = ["one.py", "two.py", "three.py"]
    outs, gates = [], []
    for order in (names, list(reversed(names)), names[1:] + names[:1]):
        gate = tmp_path / "gate.json"
        argv = ["--project"] + [str(tmp_path / n) for n in order] \
            + ["--json", "--gate-json", str(gate)]
        cli_main(argv)
        outs.append(capsys.readouterr().out.encode())
        gates.append(gate.read_bytes())
    assert outs[0] == outs[1] == outs[2]
    assert gates[0] == gates[1] == gates[2]


# -- suppression / baseline / CLI ---------------------------------------------


def test_pragma_allow_all_and_code_token():
    fs = lint("""
        import jax
        f = jax.jit(lambda x: x)  # dalek: allow[all]
        g = jax.jit(lambda x: x)  # dalek: allow[DLK001]
    """)
    assert active(fs) == [] and sum(f.suppressed for f in fs) == 2


def test_baseline_round_trip_and_determinism(tmp_path):
    src = "import jax\nf = jax.jit(lambda x: x)\n"
    fs = lint(src)
    bl = tmp_path / "baseline.json"
    baseline_mod.save(fs, bl)
    first = bl.read_bytes()
    baseline_mod.save(fs, bl)
    assert bl.read_bytes() == first            # byte-stable
    doc = json.loads(first)
    assert doc["counts"] == {"DLK001": 1}
    assert doc["findings"] == sorted(doc["findings"],
                                     key=lambda e: (e["code"], e["path"],
                                                    e["line_text"]))
    fs2 = lint(src)
    baseline_mod.apply(fs2, baseline_mod.load(bl))
    assert all(f.baselined for f in fs2) and active(fs2) == []


def test_cli_exit_codes_and_gate_json(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\nf = jax.jit(lambda x: x)\n")
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert cli_main([str(bad)]) == 1
    assert cli_main([str(good)]) == 0
    # --write-baseline grandfathers the finding; --baseline then passes
    bl = tmp_path / "bl.json"
    assert cli_main([str(bad), "--baseline-file", str(bl),
                     "--write-baseline"]) == 0
    assert cli_main([str(bad), "--baseline-file", str(bl), "--baseline"]) == 0
    gate = tmp_path / "gate.json"
    assert cli_main([str(bad), "--gate-json", str(gate)]) == 1
    rows = json.loads(gate.read_text())
    assert rows["analysis/total"]["findings"] == 1
    assert rows["analysis/DLK001"]["findings"] == 1
    # zero rows exist for every registered rule (first firing must gate)
    for code in rule_codes():
        assert f"analysis/{code}" in rows


def test_gate_rows_shape():
    rows = gate_rows([])
    assert all(v == {"findings": 0} for v in rows.values())
    assert "analysis/total" in rows


# -- the repo itself is clean --------------------------------------------------


def test_repo_is_lint_clean_modulo_baseline():
    paths = [str(REPO / p) for p in
             ("src", "benchmarks", "examples", "tests")]
    findings = analyze_paths(paths)
    baseline_mod.apply(findings, baseline_mod.load())
    assert [f.render() for f in findings if f.active] == []


def test_repo_is_project_clean_modulo_baseline():
    # the CI invocation: whole-program mode over every tree, so the
    # interprocedural rules (DLK009-DLK012) see cross-module call edges
    paths = [str(REPO / p) for p in
             ("src", "benchmarks", "examples", "tests")]
    findings = analyze_project(paths)
    baseline_mod.apply(findings, baseline_mod.load())
    assert [f.render() for f in findings if f.active] == []


def test_checked_in_baseline_has_no_bare_jit():
    # ISSUE policy: DLK001 violations are fixed, never grandfathered
    keys = baseline_mod.load()
    assert not any(code == "DLK001" for code, _, _ in keys)
