"""Serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --smoke \
        --requests 6 --max-new 16 --engine continuous --power-cap 150

Serves synthetic prompts through either engine — ``static`` (padded batch,
lock-step decode) or ``continuous`` (request queue, slot recycling,
energy-aware admission) — with per-request energy attribution from the
``repro.telemetry`` tag bus and a typed ``EnergyReport`` summary.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.models.registry import serving_caps
from repro.obs import write_chrome_trace
from repro.serve.engine import ContinuousEngine, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=["static", "continuous"],
                    default="continuous")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--power-cap", type=float, default=None,
                    help="node power cap in W (continuous engine only)")
    ap.add_argument("--prefill-buckets", default="auto",
                    help="prompt-length bucketing: 'auto' (power-of-two "
                         "edges, bounded prefill compiles), 'off' (exact "
                         "lengths, one executable per distinct length), or "
                         "explicit comma-separated edges like '8,16,32'")
    ap.add_argument("--kv-block-size", default="auto",
                    help="paged KV cache block size (continuous engine): "
                         "'auto' (largest power-of-two <= 32 dividing "
                         "max-seq; falls back to contiguous for model "
                         "families that cannot page), 'off' (contiguous "
                         "per-slot cache), or an explicit size dividing "
                         "max-seq")
    ap.add_argument("--prefix-cache", default="auto",
                    choices=["auto", "on", "off"],
                    help="radix prefix cache over prompt blocks (requires "
                         "paged KV): shared prompt prefixes prefill once; "
                         "'auto' enables it exactly when the model family "
                         "supports paged KV")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/chrome-trace timeline JSON: "
                         "request-lifecycle + engine-step spans with "
                         "per-span attributed joules")
    ap.add_argument("--metrics-json", default=None,
                    help="write the engine metrics-registry snapshot "
                         "(deterministic JSON)")
    args = ap.parse_args(argv)
    buckets = (args.prefill_buckets
               if args.prefill_buckets in ("auto", "off")
               else [int(b) for b in args.prefill_buckets.split(",")])
    kv_block = (args.kv_block_size if args.kv_block_size in ("auto", "off")
                else int(args.kv_block_size))

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    caps = serving_caps(cfg)
    # Fail fast on flag/family combinations the engine would reject later,
    # with the flag value that fixes them.
    if args.prefix_cache == "on" and not caps.prefix_cache:
        ap.error(f"--prefix-cache on: the {cfg.family!r} family serves "
                 f"through the {caps.kind!r} adapter, which has no paged KV "
                 f"to share prefixes in (use --prefix-cache auto)")
    if isinstance(kv_block, int) and not caps.paged_kv:
        ap.error(f"--kv-block-size {kv_block}: the {cfg.family!r} family "
                 f"cannot page its cache (use --kv-block-size auto)")
    if isinstance(buckets, list) and not caps.bucketed_prefill:
        ap.error(f"--prefill-buckets {args.prefill_buckets}: the "
                 f"{cfg.family!r} family prefills chunked left-to-right, "
                 f"not right-padded to buckets (use --prefill-buckets auto)")
    if args.engine == "static" and caps.kind == "recurrent":
        ap.error(f"--engine static: the {cfg.family!r} family carries "
                 f"recurrent state, which right-padded batch prefill would "
                 f"corrupt (use --engine continuous)")
    use_prefix = (caps.prefix_cache if args.prefix_cache == "auto"
                  else args.prefix_cache == "on")

    model = build_model(cfg, q_block=min(64, args.prompt_len))
    params, _ = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    frames = None
    if caps.needs_frames:
        # synthetic encoder frames stand in for a log-mel front-end
        frames = [rng.standard_normal((cfg.enc_seq, cfg.d_model))
                  .astype(np.float32) for _ in range(args.requests)]
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    frames=frames[i] if frames is not None else None)
            for i in range(args.requests)]

    if args.engine == "static":
        engine = ServeEngine(model, params, batch_size=args.batch,
                             max_seq=args.max_seq, prefill_buckets=buckets)
        stats = {}
        for i in range(0, len(reqs), args.batch):
            group = engine.serve(reqs[i:i + args.batch])
            for k, v in group.items():
                # compile counts are engine-lifetime cumulative, not per-call
                if isinstance(v, (int, float)) and not k.endswith("_compiles"):
                    stats[k] = stats.get(k, 0.0) + v
        stats["decode_tok_per_s"] = (stats["tokens_decoded"] /
                                     stats["decode_s"] if stats.get("decode_s")
                                     else 0.0)
        stats["energy_by_tag"] = dict(engine.tel.session.report().by_tag)
        stats["prefill_compiles"] = engine.trace_stats.compiles("prefill")
        stats["decode_compiles"] = engine.trace_stats.compiles("decode")
    else:
        engine = ContinuousEngine(model, params, batch_size=args.batch,
                                  max_seq=args.max_seq,
                                  power_cap_w=args.power_cap,
                                  prefill_buckets=buckets,
                                  kv_block_size=kv_block,
                                  prefix_cache=use_prefix)
        stats = engine.serve(reqs)

    print(f"arch={cfg.name} engine={args.engine} "
          f"adapter={stats.get('adapter', 'static')} family={cfg.family} "
          f"reqs={args.requests} "
          f"prefill={stats['prefill_s']*1e3:.0f}ms "
          f"decode={stats['decode_s']*1e3:.0f}ms "
          f"({stats['decode_tok_per_s']:.1f} tok/s)")
    print(f"compiles: prefill={stats['prefill_compiles']} "
          f"decode={stats['decode_compiles']} "
          f"buckets={list(engine.buckets) if engine.buckets else 'off'}")
    if stats.get("kv_block_size"):
        pc = stats.get("prefix_cache")
        pc_str = (f" prefix-cache hit-rate={pc['hit_rate']:.0%} "
                  f"cached-tokens={pc['cached_tokens']}" if pc else "")
        print(f"paged-kv: block={stats['kv_block_size']} "
              f"peak-blocks={stats['kv_pages']['peak_used']}/"
              f"{stats['kv_pages']['total_blocks']}{pc_str}")
    if engine.tel is not None:
        # full-session telemetry report from the unified API
        rep = engine.tel.session.report(tokens=stats.get("tokens_decoded"))
        print(f"energy: {rep}")
    if args.trace_out and engine.tracer is not None:
        write_chrome_trace(
            args.trace_out, engine.tracer,
            session=engine.tel.session if engine.tel is not None else None,
            meta={"process": "dalek-serve", "arch": cfg.name,
                  "engine": args.engine})
        print(f"timeline -> {args.trace_out}")
    if args.metrics_json:
        engine.metrics.write_json(args.metrics_json)
        print(f"metrics -> {args.metrics_json}")
    for r in reqs:
        j_tok = r.energy_j / max(len(r.output), 1)
        print(f"  req {r.req_id}: {len(r.output)} tokens "
              f"[{r.finish_reason or 'ok'}] {r.energy_j:.2f} J "
              f"({j_tok:.3f} J/token)")
    return stats


if __name__ == "__main__":
    main()
