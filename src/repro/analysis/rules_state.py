"""DLK008 state-reset-pairing.

Releasing a serving slot recycles its index for the next request, but
the backend state the slot owned — KV pages, a ring row, carried
recurrent state — lives in the adapter, not the ``SlotManager``. A
``slots.release(slot)`` with no adapter reset/free on the prior
occupant leaks that state into the next request: for paged KV the
pages pin forever, for recurrent families the new prompt *continues
the previous conversation's hidden state*, which is silent output
corruption rather than a crash. The rule is lexical: a ``release``
call on a slot-manager-shaped receiver must be preceded, in the same
function, by an adapter-side reset/free call (``free_slot``,
``release_slot``, ``reset_slot``, ``reset_cache_slot``, ``free``, or
``reset``). ``self.release`` (the manager's own implementation) is
exempt, same as DLK006's ``self.alloc`` carve-out.

Policy mirrors DLK001: findings are *fixed*, never baselined — pairing
the release is a one-line fix and grandfathering it would grandfather
cross-request state leakage.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import (Finding, ModuleContext, Rule, qualname,
                                 register)

#: adapter-side calls that scrub a slot's backend state before reuse
_RESETISH = ("free_slot", "release_slot", "reset_slot", "reset_cache_slot",
             "free", "reset")


def _slot_receiver(func) -> Optional[str]:
    """Receiver text if this is ``<slots>.release`` on something
    slot-manager-shaped. ``self.release`` (the manager's own method) is
    exempt — the manager resets its *own* bookkeeping there; the pairing
    obligation is on the caller that owns the adapter."""
    if not isinstance(func, ast.Attribute) or func.attr != "release":
        return None
    recv = qualname(func.value)
    if not recv or recv == "self":
        return None
    probe = recv[5:] if recv.startswith("self.") else recv
    if "slot" in probe.lower():
        return recv
    return None


@register
class StateResetPairing(Rule):
    """Slot released for reuse without adapter reset/free of its state."""

    code = "DLK008"
    name = "state-reset-pairing"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            recv = _slot_receiver(node.func)
            if recv is None:
                continue
            fn = ctx.enclosing_function(node)
            scope = fn if fn is not None else ctx.tree
            paired = any(
                isinstance(prior, ast.Call)
                and isinstance(prior.func, ast.Attribute)
                and prior.func.attr in _RESETISH
                and prior.lineno <= node.lineno
                for prior in ast.walk(scope))
            if not paired:
                yield ctx.finding(
                    self, node,
                    f"{recv}.release(...) recycles the slot without an "
                    "adapter reset/free of the prior occupant's state — "
                    "the next request inherits its pages/ring/recurrent "
                    "state (call free_slot/reset_cache_slot first)")
