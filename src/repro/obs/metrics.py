"""Labeled metrics registry: Counter / Gauge / Histogram.

Replaces the engines' ad-hoc run-stats dicts with one instrumented store
that every layer (serve, train, cluster) shares:

    m = MetricsRegistry()
    m.counter("tokens_decoded").inc(8)
    m.counter("requests_finished", "requests by finish reason").inc(
        reason="eos")
    m.gauge("queue_depth").set(3)
    m.histogram("decode_step_s").observe(0.0123)

Snapshots are **deterministic**: ``snapshot()`` orders metrics and label
sets lexicographically and ``to_json()`` serializes with sorted keys and
fixed separators, so two identical runs produce byte-identical output (a
tested invariant — diffs of metrics dumps are signal, never churn).
``prometheus()`` renders the standard text exposition format for scraping.

Floats are emitted as-is (no rounding): determinism comes from identical
arithmetic on identical runs, not from lossy formatting.
"""
from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

LabelKey = Tuple[Tuple[str, str], ...]

#: default histogram buckets (seconds-flavored, exponential)
DEFAULT_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                   0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonically increasing per-label-set totals."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels):
        if value < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(_label_key(labels), 0.0)

    def total(self) -> float:
        """Sum across all label sets."""
        with self._lock:
            return sum(self._values.values())

    def _rows(self):
        return [(key, {"value": v})
                for key, v in sorted(self._values.items())]


class Gauge(_Metric):
    """Point-in-time values (queue depth, free blocks, watts)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels):
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def add(self, value: float, **labels):
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(_label_key(labels), 0.0)

    def _rows(self):
        return [(key, {"value": v})
                for key, v in sorted(self._values.items())]


class Histogram(_Metric):
    """Cumulative-bucket distribution (Prometheus semantics: ``le`` buckets
    count observations <= the edge, plus ``+Inf``, sum, and count)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        edges = sorted(float(b) for b in buckets)
        if not edges:
            raise ValueError(f"histogram {self.name}: no buckets")
        self.edges = edges
        self._counts: Dict[LabelKey, List[int]] = {}   # per-edge (+Inf last)
        self._sum: Dict[LabelKey, float] = {}
        self._n: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels):
        key = _label_key(labels)
        i = bisect.bisect_left(self.edges, float(value))
        with self._lock:
            counts = self._counts.setdefault(key, [0] * (len(self.edges) + 1))
            counts[i] += 1
            self._sum[key] = self._sum.get(key, 0.0) + float(value)
            self._n[key] = self._n.get(key, 0) + 1

    def count(self, **labels) -> int:
        with self._lock:
            return self._n.get(_label_key(labels), 0)

    def sum(self, **labels) -> float:
        with self._lock:
            return self._sum.get(_label_key(labels), 0.0)

    def _rows(self):
        out = []
        for key in sorted(self._counts):
            cum, buckets = 0, {}
            for edge, c in zip(self.edges, self._counts[key]):
                cum += c
                buckets[repr(edge)] = cum
            buckets["+Inf"] = cum + self._counts[key][-1]
            out.append((key, {"buckets": buckets, "sum": self._sum[key],
                              "count": self._n[key]}))
        return out


class MetricsRegistry:
    """Get-or-create store of named metrics; the unit every subsystem
    instruments against and every snapshot/exposition reads from."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._metrics

    # -- snapshots ------------------------------------------------------------

    def snapshot(self) -> Dict:
        """Deterministic nested dict: metric name -> {kind, help, series}
        with series keyed by the canonical label string."""
        out: Dict = {}
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            with m._lock:
                rows = m._rows()
            out[name] = {"kind": m.kind, "help": m.help,
                         "series": {_label_str(k) or "{}": v
                                    for k, v in rows}}
            if isinstance(m, Histogram):
                out[name]["bucket_edges"] = [repr(e) for e in m.edges]
        return out

    def to_json(self) -> str:
        """Byte-deterministic JSON dump of :meth:`snapshot`."""
        return json.dumps(self.snapshot(), sort_keys=True,
                          separators=(",", ":"))

    def write_json(self, path) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return str(path)

    def prometheus(self) -> str:
        """Prometheus text exposition (``# HELP``/``# TYPE`` + samples)."""
        lines: List[str] = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            with m._lock:
                rows = m._rows()
            for key, row in rows:
                if m.kind == "histogram":
                    for edge, cum in row["buckets"].items():
                        le = (key + (("le", edge),))
                        lines.append(f"{name}_bucket{_label_str(le)} {cum}")
                    lines.append(f"{name}_sum{_label_str(key)} {row['sum']}")
                    lines.append(
                        f"{name}_count{_label_str(key)} {row['count']}")
                else:
                    lines.append(f"{name}{_label_str(key)} {row['value']}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self):
        """Drop every metric (benchmark warmup reset)."""
        with self._lock:
            self._metrics = {}
