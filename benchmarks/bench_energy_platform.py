"""Paper Sec. 4: energy measurement platform throughput + resolution.

Derived columns assert the platform's headline numbers: 1000 SPS per probe,
milliwatt resolution, 12-probe aggregation, tag attribution overhead — and
the comparison against GRID'5000 (~50 SPS @ 0.1 W).

Everything runs through the unified ``repro.telemetry`` API. Old-vs-columnar
rows time the legacy per-``Sample``-object path (``session.board``) against
the columnar ``SampleBlock`` path on identical streams (same probe seeds ->
bit-equal watts), assert the energy totals agree to 1e-9 J, and report the
speedup. The record->reload rows time the ``repro.tracestore`` ``.dkt``
round trip (write a session's stream, mmap it back) and assert the reloaded
columns are bit-exact. ``--json PATH`` dumps every row for the CI
perf-trajectory artifact.

    PYTHONPATH=src python -m benchmarks.bench_energy_platform [--json PATH]
"""
import argparse
import os
import tempfile

import numpy as np

from benchmarks.common import BenchRows, time_fn
from repro.telemetry import (MILLIWATT, REPORT_SPS, MonitorSession,
                             MutableSource, ProbeConfig, read_vectorized)
from repro.tracestore import TraceReader, TraceWriter

READ_S = 0.5        # 12-probe read window (6000 samples/call)
TAG_S = 2.0         # single-probe tag-attribution window (2000 samples)

ROWS = BenchRows()
record = ROWS.record


def _session(power_fn, n_probes=1):
    """Identically seeded sessions produce bit-equal streams, so the
    legacy and columnar paths can be compared head to head."""
    return MonitorSession([power_fn] * n_probes, node="bench")


def _bench_tracestore():
    """record -> reload: .dkt write+read round-trip overhead, bit-exact."""
    src = MutableSource(95.0)
    session = MonitorSession(src, node="trace-bench")
    with session.region("step"):
        for _ in range(10):
            session.sample(0.1)             # 10 windows, 1000 samples
    blocks = session.blocks()
    n = sum(b.n for b in blocks)
    path = os.path.join(tempfile.mkdtemp(prefix="dkt_bench_"), "bench.dkt")

    def write():
        with TraceWriter(path) as w:
            sid = w.add_stream("bench/probe0", node="bench", sps=REPORT_SPS)
            for b in blocks:
                w.append(sid, b)
        return path

    t_w = time_fn(write, warmup=1, iters=5)
    nbytes = os.path.getsize(path)

    def read():
        with TraceReader(path) as r:
            return r.read(0).energy_j()

    t_r = time_fn(read, warmup=1, iters=5)
    with TraceReader(path) as r:
        back = r.read(0)
        live = session.block()
        assert np.array_equal(live.t, back.t)
        assert np.array_equal(live.watts, back.watts)
        assert np.array_equal(live.bits, back.bits)
        assert live.energy_j() == back.energy_j()
    record("energy/trace_record", t_w,
           f"{n / t_w:.0f}samples/s_written;{nbytes / t_w / 1e6:.0f}MB/s;"
           f"{nbytes / n:.1f}B/sample")
    record("energy/trace_reload", t_r,
           f"{n / t_r:.0f}samples/s_read;roundtrip=bit_exact")
    os.unlink(path)


def run(json_path=None):
    power = lambda t: 80.0 + 10 * np.sin(t)   # noqa: E731 — array-capable

    # -- main-board read: legacy Sample objects vs columnar SampleBlock -----
    s_leg, s_col = _session(power, 12), _session(power, 12)
    t_leg = time_fn(lambda: s_leg.board.read_samples(READ_S),
                    warmup=1, iters=3)
    t_col = time_fn(lambda: s_col.board.read_block(READ_S),
                    warmup=1, iters=3)
    n_samples = 12 * int(READ_S * REPORT_SPS)
    record("energy/mainboard_read_legacy", t_leg,
           f"{n_samples / t_leg:.0f}samples/s_processed;hw_rate={REPORT_SPS}SPS")
    record("energy/mainboard_read_columnar", t_col,
           f"{n_samples / t_col:.0f}samples/s_processed;"
           f"speedup={t_leg / t_col:.1f}x_vs_legacy")

    t = time_fn(lambda: read_vectorized(lambda x: 95.0, 0.0, 10.0),
                warmup=1, iters=3)
    record("energy/probe_vectorized_10s", t,
           f"{10 * REPORT_SPS / t:.0f}samples/s;res={MILLIWATT * 1e3:.0f}mW")

    # -- tag attribution: identical streams through both reduction paths ----
    s_leg, s_col = _session(power), _session(power)
    with s_leg.region("fwd"):
        samples = s_leg.board.read_samples(TAG_S)[0]
    with s_col.region("fwd"):
        block = s_col.board.read_block(TAG_S)[0]
    assert np.array_equal([s.watts for s in samples], block.watts), \
        "legacy and columnar reads diverged on identical seeds"

    board = type(s_leg.board)
    t_leg = time_fn(lambda: board.energy_by_tag(samples), warmup=1, iters=5)
    t_col = time_fn(lambda: block.energy_by_tag(), warmup=1, iters=5)
    legacy_by_tag = board.energy_by_tag(samples)
    col_by_tag = block.energy_by_tag()
    err = max(abs(legacy_by_tag[k] - col_by_tag.get(k, 0.0))
              for k in legacy_by_tag)
    assert err < 1e-9, f"energy_by_tag paths disagree by {err} J"
    record("energy/tag_attribution_legacy", t_leg,
           f"grid5000_ratio={REPORT_SPS / 50:.0f}x")
    record("energy/tag_attribution_columnar", t_col,
           f"speedup={t_leg / t_col:.1f}x_vs_legacy;match_err={err:.1e}J")

    # -- end-to-end MonitorSession sampling (the API every consumer uses) ---
    session = MonitorSession(MutableSource(95.0), node="bench",
                             probe_cfg=ProbeConfig())
    t = time_fn(lambda: session.sample(READ_S, tags=("step",)),
                warmup=1, iters=3)
    record("energy/session_sample", t,
           f"{READ_S * REPORT_SPS / t:.0f}samples/s")

    # -- trace store: record -> reload round trip ---------------------------
    _bench_tracestore()

    ROWS.dump(json_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="dump rows as JSON (CI perf-trajectory artifact)")
    run(ap.parse_args().json)
