"""Dense decoder-only transformer stack (scan-over-layers).

Covers granite-20b, deepseek-coder-33b, qwen3-32b, gemma3-27b (5:1
local:global via per-layer scanned flags) and the internvl2-76b backbone
(patch-embedding prefix from the stub frontend). MoE layers plug in through
``repro.models.moe``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common, moe as moe_mod
from repro.models.common import ParamBuilder
from repro.parallel.sharding import Sharder


def layer_flags(cfg: ModelConfig) -> np.ndarray:
    """Per-layer is_global flag (gemma3 local:global pattern)."""
    if cfg.local_global_period <= 0:
        return np.ones((cfg.num_layers,), np.bool_)
    idx = np.arange(cfg.num_layers)
    return (idx + 1) % cfg.local_global_period == 0


class DecoderLM:
    """Functional decoder-only LM; params are explicit pytrees."""

    def __init__(self, cfg: ModelConfig, mesh=None, *, attn_impl="blocked",
                 q_block=512, remat=True, shd_rules=None, barrier=False,
                 scores_f32=True, carry_barrier=False, moe_impl="gspmd"):
        self.cfg = cfg
        self.shd = Sharder(mesh, rules=shd_rules, barrier=barrier)
        self.attn_impl = attn_impl
        self.q_block = q_block
        self.remat = remat
        self.scores_f32 = scores_f32
        # pin the scan carry inside the (remat) body: stops XLA:CPU from
        # hoisting a whole-stash bf16->f32 convert out of the backward loop
        self.carry_barrier = carry_barrier
        self.moe_impl = moe_impl
        self.n_scan = cfg.num_layers - cfg.first_k_dense

    # -- params ------------------------------------------------------------

    def init(self, key):
        cfg = self.cfg
        pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
        common.embed_init(pb, cfg)
        if cfg.frontend_stub and cfg.family == "vlm":
            pb.dense("patch_proj", (cfg.d_model, cfg.d_model), ("embed", None),
                     fan_in=cfg.d_model)
        lb = pb.child("layers")
        self._layer_init(lb, cfg, self.n_scan)
        if cfg.first_k_dense:
            db = pb.child("dense_prefix")
            for i in range(cfg.first_k_dense):
                sub = db.child(f"layer_{i}")
                self._dense_layer_init(sub, cfg, None)
        return pb.build()

    def _dense_layer_init(self, pb, cfg, L):
        pre_ax = ("layers",) if L is not None else ()
        pre = (L,) if L is not None else ()
        pb.dense("norm1", pre + (cfg.d_model,), pre_ax + ("norm",), zero=True)
        pb.dense("norm2", pre + (cfg.d_model,), pre_ax + ("norm",), zero=True)
        ab = pb.child("attn")
        common.attn_init(ab, cfg, L)
        mb = pb.child("mlp")
        if cfg.is_moe:
            # deepseek-moe style: dense-prefix FFN matches total activated width
            d_ff = cfg.moe_d_ff * (cfg.num_shared_experts + cfg.experts_per_token)
        else:
            d_ff = cfg.d_ff
        common.mlp_init(mb, cfg.d_model, d_ff, L)

    def _layer_init(self, pb, cfg, L):
        if cfg.is_moe:
            pre = (L,)
            pre_ax = ("layers",)
            pb.dense("norm1", pre + (cfg.d_model,), pre_ax + ("norm",), zero=True)
            pb.dense("norm2", pre + (cfg.d_model,), pre_ax + ("norm",), zero=True)
            ab = pb.child("attn")
            common.attn_init(ab, cfg, L)
            eb = pb.child("moe")
            moe_mod.moe_init(eb, cfg, L)
        else:
            self._dense_layer_init(pb, cfg, L)

    # -- forward -----------------------------------------------------------

    def _block(self, x, p, *, positions, is_global, cache=None, cache_pos=None,
               is_moe=False):
        cfg, shd = self.cfg, self.shd
        h, new_cache = common.attention(
            common.rms_norm(x, p["norm1"]), p["attn"], cfg, shd,
            positions=positions, is_global=is_global,
            impl=self.attn_impl, q_block=self.q_block,
            kv_cache=cache, cache_pos=cache_pos, scores_f32=self.scores_f32)
        x = x + h
        y = common.rms_norm(x, p["norm2"])
        if is_moe:
            ff, aux = moe_mod.moe_apply(y, p["moe"], cfg, shd,
                                        impl=self.moe_impl)
        else:
            ff, aux = common.mlp(y, p["mlp"], shd), 0.0
        return x + ff, new_cache, aux

    def _run_stack(self, x, params, *, positions, caches=None, cache_pos=None,
                   true_len=None):
        """Run the layer stack.

        caches: None (training) | (k_all, v_all) stacked [L,B,T,kvh,dh]
        | {"global": (k,v), "local": (k,v)} for local:global window caches.
        Caches ride in the scan CARRY and are updated in place
        (dynamic-update-slice on the donated buffers) — a single cache copy
        lives in HBM, not the 2x of a scan-ys formulation.

        true_len: traced true prompt length for bucketed (right-padded)
        prefill — under causal masking the pad tail cannot change real
        positions, but the window *ring* caches must be built from the true
        last token, not the pad tail.
        """
        cfg = self.cfg
        flags = jnp.asarray(layer_flags(cfg))
        li0 = 0
        # unrolled dense prefix (deepseek-moe/moonshot first-k-dense)
        if cfg.first_k_dense:
            for i in range(cfg.first_k_dense):
                p = params["dense_prefix"][f"layer_{i}"]
                c = None if caches is None else (caches[0][li0], caches[1][li0])
                x, nc, _ = self._block(
                    x, p, positions=positions, is_global=flags[li0],
                    cache=c, cache_pos=cache_pos)
                if caches is not None:
                    caches = (caches[0].at[li0].set(nc[0]),
                              caches[1].at[li0].set(nc[1]))
                li0 += 1

        scan_flags = flags[li0:]
        lp = params["layers"]

        if caches is None:
            def body(carry, inp):
                xc, aux = carry
                if self.carry_barrier:
                    xc = lax.optimization_barrier(xc)
                p, flag = inp
                xc, _, a = self._block(xc, p, positions=positions,
                                       is_global=flag, is_moe=cfg.is_moe)
                return (xc, aux + a), None

            if self.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux_s), _ = lax.scan(body, (x, 0.0), (lp, scan_flags))
            return x, None, aux_s

        if isinstance(caches, dict):
            return self._run_stack_windowed(x, params, positions=positions,
                                            caches=caches, cache_pos=cache_pos,
                                            scan_flags=scan_flags,
                                            true_len=true_len)

        def body(carry, inp):
            xc, aux, ck_all, cv_all, li = carry
            p, flag = inp
            ck = lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
            cv = lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
            xc, nc, a = self._block(xc, p, positions=positions, is_global=flag,
                                    cache=(ck, cv), cache_pos=cache_pos,
                                    is_moe=cfg.is_moe)
            ck_all = lax.dynamic_update_slice_in_dim(ck_all, nc[0][None], li, 0)
            cv_all = lax.dynamic_update_slice_in_dim(cv_all, nc[1][None], li, 0)
            return (xc, aux + a, ck_all, cv_all, li + 1), None

        (x, aux_s, new_k, new_v, _), _ = lax.scan(
            body, (x, 0.0, caches[0], caches[1], jnp.int32(li0)),
            (lp, scan_flags))
        return x, (new_k, new_v), aux_s

    # -- gemma3-style local:global window caches -----------------------------

    def _ring_gather(self, k, v, s, w):
        """Last-`W`-tokens ring from fresh K/V of length s: slot j holds the
        most recent token p with p ≡ j (mod W)."""
        j = jnp.arange(w)
        p = (s - 1) - ((s - 1 - j) % w)          # may be negative: unwritten
        pc = jnp.clip(p, 0)
        ring_k = jnp.take(k, pc, axis=1)
        ring_v = jnp.take(v, pc, axis=1)
        zero = (p < 0)[None, :, None, None]
        ring_k = jnp.where(zero, 0, ring_k)
        ring_v = jnp.where(zero, 0, ring_v)
        return ring_k, ring_v

    def window_size(self):
        return max(self.cfg.sliding_window, 1)

    def _run_stack_windowed(self, x, params, *, positions, caches, cache_pos,
                            scan_flags, true_len=None):
        """Scan with lax.cond per layer: global layers use the full-length
        cache stack, local layers a window-sized ring. Cuts KV memory by
        ~window/seq for the 5/6 local layers (gemma3: 32x at 32k)."""
        cfg, shd = self.cfg, self.shd
        gk, gv = caches["global"]
        w = caches["local"][0].shape[2]
        lk, lv = caches["local"]
        s = x.shape[1]

        def global_branch(xc, p, gk, gv, lk, lv, lig, lil):
            ck = lax.dynamic_index_in_dim(gk, lig, 0, keepdims=False)
            cv = lax.dynamic_index_in_dim(gv, lig, 0, keepdims=False)
            h, nc = common.attention(
                common.rms_norm(xc, p["norm1"]), p["attn"], cfg, shd,
                positions=positions, is_global=True, impl=self.attn_impl,
                q_block=self.q_block, kv_cache=(ck, cv), cache_pos=cache_pos)
            gk = lax.dynamic_update_slice_in_dim(gk, nc[0][None], lig, 0)
            gv = lax.dynamic_update_slice_in_dim(gv, nc[1][None], lig, 0)
            return xc + h, gk, gv, lk, lv, lig + 1, lil

        def local_branch(xc, p, gk, gv, lk, lv, lig, lil):
            y = common.rms_norm(xc, p["norm1"])
            if s == 1:
                slot = cache_pos % w
                j = jnp.arange(w)
                if jnp.ndim(cache_pos) == 0:
                    k_pos = cache_pos - ((cache_pos - j) % w)
                else:                                    # per-row positions
                    cp = cache_pos[:, None]
                    k_pos = cp - ((cp - j[None, :]) % w)  # [B, w]
                ck = lax.dynamic_index_in_dim(lk, lil, 0, keepdims=False)
                cv = lax.dynamic_index_in_dim(lv, lil, 0, keepdims=False)
                h, nc = common.attention(
                    y, p["attn"], cfg, shd, positions=positions,
                    is_global=False, impl=self.attn_impl,
                    q_block=self.q_block, kv_cache=(ck, cv),
                    cache_slot=slot, cache_pos=cache_pos,
                    k_positions=k_pos, k_valid=(k_pos >= 0))
                nk, nv = nc
            else:
                # prefill: windowed attention over the input, then build ring
                h, (fk, fv) = common.attention(
                    y, p["attn"], cfg, shd, positions=positions,
                    is_global=False, impl=self.attn_impl,
                    q_block=self.q_block, return_kv=True)
                nk, nv = self._ring_gather(
                    fk.astype(lk.dtype), fv.astype(lv.dtype),
                    s if true_len is None else true_len, w)
            lk = lax.dynamic_update_slice_in_dim(lk, nk[None], lil, 0)
            lv = lax.dynamic_update_slice_in_dim(lv, nv[None], lil, 0)
            return xc + h, gk, gv, lk, lv, lig, lil + 1

        def body(carry, inp):
            xc, gk, gv, lk, lv, lig, lil = carry
            p, flag = inp
            xc, gk, gv, lk, lv, lig, lil = lax.cond(
                flag, global_branch, local_branch,
                xc, p, gk, gv, lk, lv, lig, lil)
            y = common.rms_norm(xc, p["norm2"])
            xc = xc + common.mlp(y, p["mlp"], shd)
            return (xc, gk, gv, lk, lv, lig, lil), None

        init = (x, gk, gv, lk, lv, jnp.int32(0), jnp.int32(0))
        (x, gk, gv, lk, lv, _, _), _ = lax.scan(
            body, init, (params["layers"], scan_flags))
        return x, {"global": (gk, gv), "local": (lk, lv)}, 0.0

    def _inputs_to_h(self, batch, params):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = common.embed(batch["tokens"], params, dtype)
        if cfg.family == "vlm" and "patch_embeddings" in batch:
            pe = jnp.einsum("bsd,de->bse",
                            batch["patch_embeddings"].astype(dtype),
                            params["patch_proj"].astype(dtype))
            x = jnp.concatenate([pe, x], axis=1)
        return self.shd(x, "batch", "seq", "act_embed")

    def forward(self, params, batch):
        """Training/scoring forward: batch = {tokens [B,S], (patch_embeddings)}.

        Returns (logits [B,S',V], aux_loss).
        """
        x = self._inputs_to_h(batch, params)
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._run_stack(x, params, positions=positions)
        logits = common.unembed(x, params, self.shd)
        return logits, aux

    def hidden(self, params, batch):
        """Final hidden states (pre-unembed) — used by the chunked
        cross-entropy path that never materializes full [B,S,V] logits."""
        x = self._inputs_to_h(batch, params)
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._run_stack(x, params, positions=positions)
        return x, aux

    # -- serving -----------------------------------------------------------

    def init_cache(self, batch_size, max_seq, dtype=jnp.bfloat16):
        cfg = self.cfg
        flags = layer_flags(cfg)
        if cfg.local_global_period > 0:
            n_global = int(flags.sum())
            n_local = cfg.num_layers - n_global
            w = min(self.window_size(), max_seq)
            gshape = (n_global, batch_size, max_seq, cfg.num_kv_heads,
                      cfg.head_dim)
            lshape = (n_local, batch_size, w, cfg.num_kv_heads, cfg.head_dim)
            return {
                "global": (jnp.zeros(gshape, dtype), jnp.zeros(gshape, dtype)),
                "local": (jnp.zeros(lshape, dtype), jnp.zeros(lshape, dtype)),
            }
        shape = (cfg.num_layers, batch_size, max_seq, cfg.num_kv_heads,
                 cfg.head_dim)
        k = jnp.zeros(shape, dtype)
        return (k, jnp.zeros(shape, dtype))

    def cache_axes(self):
        ax = ("layers", "batch", "kv_seq", "act_kv_heads", None)
        if self.cfg.local_global_period > 0:
            # ring (window) caches are small: never worth seq-sharding
            axl = ("layers", "batch", None, "act_kv_heads", None)
            return {"global": (ax, ax), "local": (axl, axl)}
        return (ax, ax)

    def prefill(self, params, batch, caches, true_len=None, start_pos=None):
        """Prefill: writes KV caches at [start, start+S); returns
        (logits_last, caches).

        true_len: optional traced scalar for bucketed (right-padded)
        prompts — window ring caches are built from the true last token and
        the returned logits come from position ``true_len - 1`` instead of
        the pad tail. Cache positions >= true_len still hold pad KV; the
        serving steps zero them via ``common.mask_cache_tail``.

        start_pos: optional traced scalar offsetting the chunk (paged
        serving's prefix-cache tail prefill): token i of the batch sits at
        absolute position ``start_pos + i``, attends causally over the
        cache prefix [0, start_pos) already in ``caches`` plus itself, and
        ``true_len`` stays chunk-relative. Windowed ring caches can't
        resume a ring mid-stream, so chunked prefill is flat-cache only."""
        if start_pos is not None:
            assert not isinstance(caches, dict), (
                "chunked prefill (start_pos) is not supported for "
                "local:global window ring caches")
        x = self._inputs_to_h(batch, params)
        offset = jnp.int32(0) if start_pos is None else start_pos
        positions = jnp.arange(x.shape[1]) + offset
        x, caches, _ = self._run_stack(x, params, positions=positions,
                                       caches=caches, cache_pos=offset,
                                       true_len=true_len)
        if true_len is None:
            last = x[:, -1:]
        else:
            last = lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
        logits = common.unembed(last, params, self.shd)
        return logits, caches

    def decode_step(self, params, token, pos, caches):
        """One decode step. token: [B,1] int32; pos: scalar int32 or [B]
        int32 (continuous batching: each batch row decodes at its own
        position; rows attend only to their own cache prefix)."""
        dtype = jnp.dtype(self.cfg.dtype)
        x = common.embed(token, params, dtype)
        x = self.shd(x, "batch", "seq", "act_embed")
        if jnp.ndim(pos) == 0:
            positions = jnp.array([0], jnp.int32) + pos
        else:
            positions = pos.astype(jnp.int32)[:, None]   # [B, 1]
        x, caches, _ = self._run_stack(x, params, positions=positions,
                                       caches=caches, cache_pos=pos)
        logits = common.unembed(x, params, self.shd)
        return logits, caches
