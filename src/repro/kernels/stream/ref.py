"""Pure-jnp oracle for the STREAM suite (paper Sec. 5.1 semantics)."""
import jax.numpy as jnp


def copy(a):
    return a


def scale(a, x):
    return a * jnp.asarray(x, a.dtype)


def add(a, b):
    return a + b


def triad(a, b, x):
    return jnp.asarray(x, a.dtype) * a + b


def write(shape, x, dtype=jnp.float32):
    return jnp.full(shape, x, dtype)


def read(a, block_rows=256):
    rows, cols = a.shape
    block_rows = min(block_rows, rows)
    return a.reshape(rows // block_rows, block_rows * cols).sum(
        axis=1, keepdims=True)
