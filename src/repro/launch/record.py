"""Cluster trace recording driver.

    PYTHONPATH=src python -m repro.launch.record --out trace.dkt \
        --partition az5-a890m --nodes 2 --duration 1.0 --step 0.05

Attaches one probe per chip on each selected node of the paper's topology
(``ClusterRecorder``), drives every chip with a deterministic synthetic
utilization schedule (idle..TDP sinusoid, per-node phase offset), and
writes one multi-stream ``.dkt`` trace. The output replays with
``python -m repro.launch.replay``.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.cluster.topology import dalek_topology
from repro.tracestore import ClusterRecorder, TraceReader


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace.dkt")
    ap.add_argument("--partition", default="az5-a890m",
                    help="paper partition to record from")
    ap.add_argument("--nodes", type=int, default=2,
                    help="number of nodes (probed one session each)")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="recording length in seconds (session clock)")
    ap.add_argument("--step", type=float, default=0.05,
                    help="host power-update period (one window per step)")
    ap.add_argument("--util-hz", type=float, default=3.0,
                    help="synthetic utilization oscillation rate")
    args = ap.parse_args(argv)

    topo = dalek_topology()
    names = topo.partition_nodes(args.partition)[:args.nodes]
    if len(names) < args.nodes:
        raise SystemExit(f"partition {args.partition} has only "
                         f"{len(names)} nodes")

    with ClusterRecorder(topo, args.out, nodes=names,
                         meta={"workload": "synthetic-sin",
                               "partition": args.partition}) as rec:
        energy = 0.0
        while rec.cursor < args.duration - 1e-12:
            t = rec.cursor
            for j, name in enumerate(names):
                node = topo.nodes[name]
                u = 0.5 + 0.5 * np.sin(args.util_hz * t + j)
                rec.set_power(name, [d.idle_w + (d.tdp_w - d.idle_w) * u
                                     for d in node.spec.devices])
            energy += rec.sample(min(args.step, args.duration - t),
                                 tags=("record",))
        path = rec.close()

    with TraceReader(path) as r:
        print(f"recorded {path}: {len(r.streams)} streams, "
              f"{r.n_samples()} samples, {os.path.getsize(path)} bytes")
        for s in r.streams:
            t0, t1 = r.time_range(s["id"])
            print(f"  stream {s['id']}: {s['name']} ({s['device']}) "
                  f"sps={s['sps']:.0f} span=[{t0:.3f}, {t1:.3f}]s")
    print(f"cluster energy: {energy:.3f} J over {args.duration:.3f} s")
    return path


if __name__ == "__main__":
    main()
