"""Recurrent-family serving under the ``CacheAdapter`` layer.

The load-bearing invariant: continuous batching must be *invisible* to a
recurrent model. Carried state (mLSTM cells, Mamba SSM state, conv
carries, Whisper KV) lives in per-slot rows that the adapter gathers,
steps, and scatters — so batched decode with slot reuse/reset in any
order must be bit-exact against serving the same requests one at a time
through a batch-1 engine. Right-pad corruption, stale-state leaks on
slot recycling, and cross-row bleed all break this equality on the
first divergent token.

Compile discipline rides along: chunked left-to-right prefill decomposes
prompt lengths into powers of two, so prefill executables are bounded by
the number of distinct chunk sizes (+1 for the frames-carrying first
chunk on audio), and fused decode compiles exactly once.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax

from repro import configs
from repro.models import build_model
from repro.models.registry import serving_caps
from repro.serve.engine import ContinuousEngine, Request
from repro.serve.step import pow2_chunks

MAX_SEQ = 32
RECURRENT_ARCHS = ["xlstm-1.3b", "zamba2-1.2b", "whisper-small"]


@pytest.fixture(scope="module", params=RECURRENT_ARCHS)
def family(request):
    cfg = configs.get_smoke(request.param)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


def _mk_reqs(cfg, plens, seed, max_new=5):
    """Requests whose content depends only on (seed, index) — identical
    across the batched and sequential runs regardless of issue order."""
    caps = serving_caps(cfg)
    reqs = []
    for i, plen in enumerate(plens):
        rng = np.random.default_rng(seed * 997 + i)
        kw = {}
        if caps.needs_frames:
            kw["frames"] = rng.standard_normal(
                (cfg.enc_seq, cfg.d_model)).astype(np.float32)
        reqs.append(Request(i, rng.integers(1, cfg.vocab_size, plen)
                            .astype(np.int32), max_new_tokens=max_new, **kw))
    return reqs


def _check_batched_matches_sequential(cfg, model, params, plens, seed,
                                      batch_size=3, max_new=5):
    batched = _mk_reqs(cfg, plens, seed, max_new)
    solo = _mk_reqs(cfg, plens, seed, max_new)
    eb = ContinuousEngine(model, params, batch_size=batch_size,
                          max_seq=MAX_SEQ, telemetry=False)
    eb.serve(batched)
    es = ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                          telemetry=False)
    for r in solo:                      # one request at a time, slot 0 reused
        es.serve([r])
    for rb, rs in zip(batched, solo):
        assert len(rb.output) == max_new
        assert rb.output == rs.output, (
            f"req {rb.req_id} (plen {len(rb.prompt)}) diverged: "
            f"batched={rb.output} sequential={rs.output}")
    return eb


def test_batched_decode_bit_exact_seeded(family):
    """Deterministic sweep (runs without hypothesis): more requests than
    slots forces recycling, mixed lengths exercise every chunk path."""
    cfg, model, params = family
    eb = _check_batched_matches_sequential(
        cfg, model, params, plens=(3, 7, 5, 1, 6, 2, 4), seed=17)
    # compile bounds: one fused decode executable; prefill bounded by the
    # distinct pow2 chunk sizes — on audio, frames ride the *first* chunk
    # only, so first-chunk and continuation-chunk signatures count apart
    plens = (3, 7, 5, 1, 6, 2, 4)
    if serving_caps(cfg).needs_frames:
        bound = (len({pow2_chunks(p)[0] for p in plens})
                 + len({c for p in plens for c in pow2_chunks(p)[1:]}))
    else:
        bound = len({c for p in plens for c in pow2_chunks(p)})
    assert eb.trace_stats.compiles("decode") == 1
    assert eb.trace_stats.compiles("prefill") <= bound
    assert eb.trace_stats.compiles("state_scatter") == 1


def test_slot_reuse_does_not_leak_state(family):
    """A recycled slot must behave as if freshly allocated: the same
    request decodes identically as the first and the last occupant."""
    cfg, model, params = family
    first = _mk_reqs(cfg, (5,), seed=3)
    again = _mk_reqs(cfg, (5,), seed=3)
    filler = _mk_reqs(cfg, (4, 6, 2), seed=8)
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                           telemetry=False)
    eng.serve(first)
    eng.serve(filler)                  # occupy + recycle slot 0 three times
    eng.serve(again)
    assert first[0].output == again[0].output


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(plens=st.lists(st.integers(1, MAX_SEQ - 7), min_size=2,
                          max_size=5),
           seed=st.integers(0, 900))
    def test_batched_decode_bit_exact_property(family, plens, seed):
        """Property form: any (prompt lengths, content seed) mix is
        bit-exact between batched and sequential decode."""
        cfg, model, params = family
        _check_batched_matches_sequential(cfg, model, params,
                                          tuple(plens), seed)


# ---------------------------------------------------------------------------
# every configured architecture serves under the continuous engine


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_every_arch_serves_continuous(arch):
    cfg = configs.get_smoke(arch)
    caps = serving_caps(cfg)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    eng = ContinuousEngine(model, params, batch_size=2, max_seq=MAX_SEQ,
                           telemetry=False)
    reqs = _mk_reqs(cfg, (5, 3, 6), seed=1, max_new=4)
    stats = eng.serve(reqs)
    for r in reqs:
        assert len(r.output) == 4 and r.finish_reason == "length"
    assert stats["family"] == cfg.family
    assert stats["adapter"] == eng.adapter.kind == caps.kind
    assert stats["decode_compiles"] == 1


def test_audio_requires_frames():
    cfg = configs.get_smoke("whisper-small")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=MAX_SEQ,
                           telemetry=False)
    with pytest.raises(ValueError, match="frames"):
        eng.submit(Request(0, np.arange(1, 4, dtype=np.int32)))
