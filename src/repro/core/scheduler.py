"""Energy-aware heterogeneous scheduling (paper Sec. 6.1).

Two use cases from the paper, generalized into framework features:

1. *Two-resource-type task scheduling* (Orhan et al., HCW'25, extended by
   Idouar et al. with real DALEK power readings): partially-replicable task
   chains placed across two core/device classes, optimizing makespan or
   energy. We implement the list-scheduling variant with an
   energy-aware objective.

2. *Straggler mitigation for heterogeneous data parallelism*: when
   partitions differ in throughput (p-cores vs e-cores; old vs new pods),
   static equal sharding makes the slowest partition the critical path.
   The scheduler splits work proportionally to measured throughput and
   re-balances online from telemetry (the paper's probes close this loop).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hw import DeviceSpec
from repro.core.energy import DvfsState, power_w


@dataclasses.dataclass(frozen=True)
class Task:
    """One unit of a task chain. flops and replicable span (HCW'25 model)."""

    name: str
    flops: float
    replicable: bool = True      # can split across devices of one class
    deps: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class ResourceClass:
    """One device class (p-cores / e-cores; 4090s / iGPUs; pod A / pod B)."""

    name: str
    dev: DeviceSpec
    count: int
    efficiency: float = 1.0       # achievable fraction of peak


@dataclasses.dataclass
class Placement:
    task: str
    resource: str
    start_s: float
    end_s: float
    energy_j: float


class HeterogeneousScheduler:
    """List scheduler over two (or more) resource classes.

    objective: "time" (makespan), "energy" (J), or "edp" (energy-delay
    product) — the trade-off the DALEK energy platform makes measurable.
    """

    def __init__(self, classes: Sequence[ResourceClass], objective="time"):
        self.classes = list(classes)
        self.objective = objective

    def _exec_time(self, task: Task, rc: ResourceClass) -> float:
        rate = rc.dev.peak_flops * rc.efficiency
        if task.replicable:
            rate *= rc.count
        return task.flops / rate

    def _energy(self, task: Task, rc: ResourceClass, t: float) -> float:
        n = rc.count if task.replicable else 1
        return power_w(rc.dev, util=1.0) * n * t

    def _score(self, t: float, e: float) -> float:
        if self.objective == "time":
            return t
        if self.objective == "energy":
            return e
        return t * e  # edp

    def schedule(self, tasks: Sequence[Task]) -> Tuple[List[Placement], Dict]:
        """Greedy earliest-finish list scheduling with the chosen objective."""
        ready_at = {rc.name: 0.0 for rc in self.classes}
        done_at: Dict[str, float] = {}
        placements: List[Placement] = []
        pending = list(tasks)
        scheduled = set()
        while pending:
            progressed = False
            for task in list(pending):
                if any(d not in done_at for d in task.deps):
                    continue
                dep_ready = max([done_at[d] for d in task.deps], default=0.0)
                best = None
                for rc in self.classes:
                    t_exec = self._exec_time(task, rc)
                    start = max(ready_at[rc.name], dep_ready)
                    end = start + t_exec
                    e = self._energy(task, rc, t_exec)
                    # score on completion time for deps + objective
                    key = (self._score(end, e), end)
                    if best is None or key < best[0]:
                        best = (key, rc, start, end, e)
                _, rc, start, end, e = best
                placements.append(Placement(task.name, rc.name, start, end, e))
                ready_at[rc.name] = end
                done_at[task.name] = end
                pending.remove(task)
                scheduled.add(task.name)
                progressed = True
            if not progressed:
                raise ValueError("dependency cycle in task chain")
        makespan = max((p.end_s for p in placements), default=0.0)
        energy = sum(p.energy_j for p in placements)
        return placements, {"makespan_s": makespan, "energy_j": energy}


# ---------------------------------------------------------------------------
# straggler mitigation: throughput-proportional work split


@dataclasses.dataclass
class WorkerStats:
    name: str
    tokens_per_s: float           # measured (telemetry) or modeled


def proportional_split(total: int, workers: Sequence[WorkerStats],
                       quantum: int = 1) -> Dict[str, int]:
    """Split ``total`` work items proportionally to throughput, quantized.

    Guarantees: sum == total; every worker >= 0; faster workers never get
    less than slower ones.
    """
    rates = np.array([max(w.tokens_per_s, 1e-9) for w in workers])
    raw = total * rates / rates.sum()
    q = np.floor(raw / quantum).astype(int) * quantum
    rem = total - int(q.sum())
    order = np.argsort(-(raw - q))
    i = 0
    while rem > 0:
        q[order[i % len(workers)]] += min(quantum, rem)
        rem -= min(quantum, rem)
        i += 1
    return {w.name: int(n) for w, n in zip(workers, q)}


class ThroughputStats:
    """EWMA throughput per phase (items/s) from engine telemetry.

    Closes the probe->scheduler loop for serving: the admission policy asks
    for the measured decode rate to predict queue wait and decide whether to
    defer or shed load (same EWMA as ``StragglerMitigator``, keyed by phase
    instead of worker).
    """

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.rates: Dict[str, float] = {}
        self.totals: Dict[str, float] = {}

    def observe(self, phase: str, items: float, seconds: float):
        r = items / max(seconds, 1e-9)
        old = self.rates.get(phase, 0.0)
        self.rates[phase] = r if old == 0.0 else (
            self.alpha * r + (1 - self.alpha) * old)
        self.totals[phase] = self.totals.get(phase, 0.0) + items

    def rate(self, phase: str, default: float = 0.0) -> float:
        return self.rates.get(phase, default)

    def predicted_wait_s(self, n_items: float, phase: str = "decode") -> float:
        """Time to clear ``n_items`` at the measured rate; inf if unmeasured."""
        r = self.rate(phase)
        return n_items / r if r > 0 else float("inf")


class StragglerMitigator:
    """Online re-balancer: EWMA throughput per worker, re-split when the
    predicted critical-path gain exceeds a threshold."""

    def __init__(self, workers: Sequence[str], alpha=0.3, threshold=0.05):
        self.rates = {w: 0.0 for w in workers}
        self.alpha = alpha
        self.threshold = threshold
        self.resplits = 0

    def observe(self, worker: str, items: int, seconds: float):
        r = items / max(seconds, 1e-9)
        old = self.rates[worker]
        self.rates[worker] = r if old == 0 else (
            self.alpha * r + (1 - self.alpha) * old)

    def current_split(self, total: int, quantum: int = 1) -> Dict[str, int]:
        ws = [WorkerStats(n, r if r > 0 else 1.0)
              for n, r in self.rates.items()]
        return proportional_split(total, ws, quantum)

    def should_resplit(self, current: Dict[str, int]) -> bool:
        """True when the balanced split beats the current one by >threshold."""
        if any(r == 0 for r in self.rates.values()):
            return False
        total = sum(current.values())
        t_now = max(current[w] / self.rates[w] for w in current)
        bal = self.current_split(total)
        t_bal = max(bal[w] / self.rates[w] for w in bal)
        gain = (t_now - t_bal) / max(t_now, 1e-9)
        if gain > self.threshold:
            self.resplits += 1
            return True
        return False
