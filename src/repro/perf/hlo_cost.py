"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-counts scanned layers / gradient-accumulation loops by orders of
magnitude. This walker parses the optimized HLO text, resolves the call graph
(fusions, while bodies with ``known_trip_count``, conditionals), and
accumulates:

  - FLOPs: 2 * prod(result_dims) * prod(contracting_dims) per dot,
  - bytes: operands + result per top-level instruction (fusion internals are
    VMEM-resident, standard cost-analysis assumption),
  - collectives: per-op traffic with replica-group sizes, multiplied by the
    enclosing loops' trip counts, split intra-pod (ICI) vs cross-pod (DCN).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*)\s*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\]{},]+))\s+([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dtype_ratio(src_type: str, res_type: str) -> float:
    """itemsize(src)/itemsize(res), capped at 1.0 (never inflate)."""
    ms = _SHAPE.search(src_type)
    mr = _SHAPE.search(res_type)
    if not ms or not mr:
        return 1.0
    s = _DTYPE_BYTES.get(ms.group(1), 4)
    r = _DTYPE_BYTES.get(mr.group(1), 4)
    return min(s / r, 1.0) if r else 1.0


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # full remainder of the line (operands + attributes)
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]          # param name -> type
    instrs: List[Instr]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and ("->" in line):
                params = {}
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|[\w\[\]{},]+)",
                                      m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(1), params, [])
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            rest = line[im.end():]
            cur.instrs.append(Instr(im.group(1), im.group(2), im.group(3), rest,
                                    "ROOT " in line[:16]))
    if cur is not None:
        comps[cur.name] = cur
    return comps


@dataclasses.dataclass
class CollectiveRec:
    op: str
    bytes_moved: float   # per-device link traffic for ONE execution
    group_size: int
    crosses_pod: bool
    count: float         # executions (includes loop trip counts)


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    attn_score_bytes: float = 0.0   # HBM traffic the flash kernel keeps in VMEM
    collectives: List[CollectiveRec] = dataclasses.field(default_factory=list)


def _parse_collective(instr: Instr, pod_block: Optional[int]
                      ) -> Tuple[float, int, bool]:
    result_bytes = _shape_bytes(instr.type_str)
    gsize, crosses = 1, False
    m = _IOTA.search(instr.rest)
    if m:
        gsize = int(m.group(2))
        if pod_block:
            # evaluate the iota replica-group list EXACTLY:
            # groups = iota(dims).transpose(perm).reshape(n_groups, g_size)
            import numpy as _np
            n_groups = int(m.group(1))
            dims = [int(d) for d in m.group(3).split(",") if d]
            ids = _np.arange(int(_np.prod(dims))).reshape(dims)
            if m.group(4):
                perm = [int(p) for p in m.group(5).split(",")]
                ids = ids.transpose(perm)
            groups = ids.reshape(n_groups, gsize)
            crosses = bool(((groups // pod_block).max(axis=1)
                            != (groups // pod_block).min(axis=1)).any())
    else:
        m = _GROUPS.search(instr.rest)
        if m:
            first = m.group(1).split("},{")[0].strip("{}")
            ids = [int(x) for x in first.split(",") if x.strip()]
            gsize = max(len(ids), 1)
            if pod_block and ids:
                crosses = (min(ids) // pod_block) != (max(ids) // pod_block)
    g = max(gsize, 1)
    op = instr.op.replace("-start", "")
    if op == "all-gather":
        b = result_bytes * (g - 1) / g
    elif op == "all-reduce":
        b = 2 * result_bytes * (g - 1) / g
    elif op == "reduce-scatter":
        b = result_bytes * (g - 1)
    elif op == "all-to-all":
        b = result_bytes * (g - 1) / g
    else:
        b = result_bytes
    return b, g, crosses


class ModuleCost:
    def __init__(self, text: str, pod_block: Optional[int] = None,
                 fused_attn_shapes: Optional[Tuple[int, int]] = None):
        self.comps = parse_module(text)
        self.pod_block = pod_block
        # (q_block, kv_len): instructions with [.., q_block, kv_len] trailing
        # dims are attention-score buffers. The framework's Pallas
        # flash_attention kernel keeps them in VMEM on TPU; with this set,
        # their HBM traffic is tracked separately (attn_score_bytes).
        self.fused_attn_shapes = fused_attn_shapes
        self.attn_score_bytes = 0.0
        self._memo: Dict[str, CostTotals] = {}
        self._types: Dict[str, Dict[str, str]] = {}
        m = re.search(r"^ENTRY %?([\w.\-]+)", text, re.M)
        self.entry = m.group(1) if m else next(iter(self.comps), "")

    def _is_score_shaped(self, type_str: str) -> bool:
        if not self.fused_attn_shapes:
            return False
        qb, t = self.fused_attn_shapes
        dims = _shape_dims(type_str)
        return (len(dims) >= 2 and dims[-1] == t
                and (dims[-2] == qb or (len(dims) >= 3 and dims[-3] == qb)))

    def _type_table(self, comp: Computation) -> Dict[str, str]:
        tbl = self._types.get(comp.name)
        if tbl is None:
            tbl = dict(comp.params)
            for i in comp.instrs:
                tbl[i.name] = i.type_str
            self._types[comp.name] = tbl
        return tbl

    def _instr_table(self, comp: Computation) -> Dict[str, Instr]:
        tbl = getattr(self, "_instrs_cache", None)
        if tbl is None:
            self._instrs_cache = tbl = {}
        sub = tbl.get(comp.name)
        if sub is None:
            sub = {i.name: i for i in comp.instrs}
            tbl[comp.name] = sub
        return sub

    def _resolve_type(self, comp: Computation, operand: str) -> str:
        return self._type_table(comp).get(operand, "")

    def _instr_of(self, comp: Computation, name: str) -> Optional[Instr]:
        return self._instr_table(comp).get(name)

    def _is_transparent_fusion(self, ins: Instr) -> bool:
        """Fusion containing ONLY dtype/layout ops: a TPU compile fuses these
        into their consumers (free); XLA:CPU materializes them because its
        dots are f32-only."""
        if ins.op not in ("fusion",):
            return False
        cm = _CALLS.search(ins.rest)
        sub = self.comps.get(cm.group(1)) if cm else None
        if sub is None:
            return False
        allowed = set(self._TRANSPARENT) | {"parameter"}
        return all(i.op in allowed for i in sub.instrs)

    _SLICEY = ("dynamic-slice", "slice", "parameter", "constant",
               "get-tuple-element")

    def _wire_dtype_ratio(self, comp: Computation, operand: str,
                          result_type: str, depth=0) -> float:
        """min-itemsize(producer elementwise chain) / itemsize(result)."""
        mr = _SHAPE.search(result_type)
        res_b = _DTYPE_BYTES.get(mr.group(1), 4) if mr else 4
        ins = self._instr_of(comp, operand)
        if ins is None or res_b == 0:
            return 1.0
        candidates = [ins.type_str]
        if ins.op == "fusion":
            cm = _CALLS.search(ins.rest)
            sub = self.comps.get(cm.group(1)) if cm else None
            if sub is not None:
                allowed = set(self._TRANSPARENT) | set(self._SLICEY)
                if all(i.op in allowed for i in sub.instrs):
                    candidates += [i.type_str for i in sub.instrs
                                   if i.op == "convert"]
        elif ins.op in self._TRANSPARENT and depth < 4:
            names = self._operands(ins)
            if names:
                return min(
                    _DTYPE_BYTES.get(_SHAPE.search(ins.type_str).group(1), 4)
                    / res_b,
                    self._wire_dtype_ratio(comp, names[0], result_type,
                                           depth + 1))
        mins = []
        for t in candidates:
            m = _SHAPE.search(t)
            if m:
                mins.append(_DTYPE_BYTES.get(m.group(1), 4))
        if not mins:
            return 1.0
        return min(min(mins) / res_b, 1.0)

    def _source_type(self, comp: Computation, operand: str, depth=0) -> str:
        """Type of an operand looking through converts/copies/transparent
        fusions — the dtype a TPU compile would actually move."""
        if depth > 6:
            return self._resolve_type(comp, operand)
        ins = self._instr_of(comp, operand)
        if ins is None:
            return self._resolve_type(comp, operand)
        if ins.op in self._TRANSPARENT or self._is_transparent_fusion(ins):
            names = self._operands(ins)
            if names:
                # pick the largest-itemsize-smallest... use first data operand
                src = self._source_type(comp, names[0], depth + 1)
                if src:
                    # keep this op's SHAPE but the source's dtype (transposes
                    # and bitcasts change layout/shape, not element count)
                    src_bytes = _shape_bytes(src)
                    own_bytes = _shape_bytes(ins.type_str)
                    return src if src_bytes <= own_bytes else ins.type_str
        return ins.type_str

    def comp_cost(self, name: str) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = CostTotals()
        if comp is None:
            self._memo[name] = total
            return total
        self._memo[name] = total  # break cycles
        for ins in comp.instrs:
            op = ins.op
            base_op = op.replace("-start", "")
            # --- flops ---
            if base_op in ("dot", "dot-general"):
                res_dims = _shape_dims(ins.type_str)
                n_res = 1
                for d in res_dims:
                    n_res *= d
                lhs_c = _LHS_C.search(ins.rest)
                contract = 1
                names = _OPERAND.findall(ins.rest.split(")", 1)[0])
                if lhs_c and names:
                    lhs_type = self._resolve_type(comp, names[0])
                    lhs_dims = _shape_dims(lhs_type)
                    for idx in lhs_c.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                total.flops += 2.0 * n_res * contract
            elif base_op == "convolution":
                # rough: 2 * result * (input feature window) — parse kernel
                res = 1
                for d in _shape_dims(ins.type_str):
                    res *= d
                names = _OPERAND.findall(ins.rest.split(")", 1)[0])
                ker = 1
                if len(names) >= 2:
                    for d in _shape_dims(self._resolve_type(comp, names[1])):
                        ker *= d
                total.flops += 2.0 * res * ker / max(
                    _shape_dims(ins.type_str)[-1] if _shape_dims(ins.type_str) else 1, 1)

            # --- control flow / calls ---
            if base_op == "fusion" or base_op == "call":
                cm = _CALLS.search(ins.rest)
                if cm:
                    sub = self.comp_cost(cm.group(1))
                    total.flops += sub.flops
                    # fusion internals are on-chip; only count its collectives
                    for c in sub.collectives:
                        total.collectives.append(c)
            elif base_op == "while":
                trips = 1
                tm = _TRIP.search(ins.rest)
                if tm:
                    trips = int(tm.group(1))
                bm = _WHILE_BODY.search(ins.rest)
                cm = _WHILE_COND.search(ins.rest)
                for sub_name in [x.group(1) for x in (bm, cm) if x]:
                    sub = self.comp_cost(sub_name)
                    total.flops += trips * sub.flops
                    total.bytes += trips * sub.bytes
                    total.attn_score_bytes += trips * sub.attn_score_bytes
                    for c in sub.collectives:
                        total.collectives.append(dataclasses.replace(
                            c, count=c.count * trips))
            elif base_op == "conditional":
                bm = _BRANCHES.search(ins.rest)
                if bm:
                    subs = [self.comp_cost(n.strip().lstrip("%"))
                            for n in bm.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s.flops + s.bytes)
                        total.flops += best.flops
                        total.bytes += best.bytes
                        total.attn_score_bytes += best.attn_score_bytes
                        total.collectives.extend(best.collectives)

            # --- collectives ---
            if base_op in COLLECTIVE_OPS and not op.endswith("-done"):
                b, g, crosses = _parse_collective(ins, self.pod_block)
                # TPU-faithful wire dtype: the narrowest dtype the operand's
                # elementwise producer chain passes through (XLA:CPU's
                # f32-only dots force f32->bf16->f32 roundtrips that a TPU
                # compile never materializes — it gathers bf16)
                names = self._operands(ins)
                if names:
                    b *= self._wire_dtype_ratio(comp, names[0], ins.type_str)
                total.collectives.append(
                    CollectiveRec(base_op, b, g, crosses, 1.0))

            # --- bytes ---
            if base_op in _SKIP_BYTES_OPS or base_op == "while":
                continue
            b = self._instr_bytes(comp, ins)
            total.bytes += b
            if self._is_score_shaped(ins.type_str):
                total.attn_score_bytes += b
        return total

    def _operands(self, ins: Instr) -> List[str]:
        return _OPERAND.findall(ins.rest.split(")", 1)[0])

    def _instr_bytes(self, comp: Computation, ins: Instr) -> float:
        """Utilization-aware bytes-accessed for one instruction (HBM side).

        Mirrors XLA HloCostAnalysis semantics: dynamic-slice reads only the
        slice, in-place dynamic-update-slice moves only the update, gathers
        read result-sized data, and fusion parameters that are only sliced
        inside the fusion contribute their sliced bytes, not the full array.
        """
        base_op = ins.op.replace("-start", "")
        names = self._operands(ins)

        if base_op == "dynamic-slice" or base_op == "slice":
            return 2.0 * _shape_bytes(ins.type_str)
        if base_op == "dynamic-update-slice":
            upd = _shape_bytes(self._resolve_type(comp, names[1])) if len(names) > 1 else 0
            return 2.0 * upd
        if base_op == "gather":
            idx = _shape_bytes(self._resolve_type(comp, names[1])) if len(names) > 1 else 0
            return 2.0 * _shape_bytes(ins.type_str) + idx
        if base_op == "scatter":
            upd = _shape_bytes(self._resolve_type(comp, names[2])) if len(names) > 2 else 0
            idx = _shape_bytes(self._resolve_type(comp, names[1])) if len(names) > 1 else 0
            return 2.0 * upd + idx

        if base_op in ("fusion", "call"):
            if self._is_transparent_fusion(ins):
                # dtype/layout-only: fused into the consumer on TPU — the
                # consumer's operand accounting (source dtype) covers it
                return 0.0
            cm = _CALLS.search(ins.rest)
            sub = self.comps.get(cm.group(1)) if cm else None
            if sub is not None:
                return self._fusion_bytes(sub, ins, names, caller=comp)

        if base_op in self._TRANSPARENT:
            return 0.0

        rb = _shape_bytes(ins.type_str)
        if base_op in ("dot", "dot-general"):
            # TPU fuses the output convert into the matmul epilogue: count
            # the result at the sink dtype when all uses narrow it
            rb *= self._sink_ratio(comp, ins)
        ob = sum(_shape_bytes(self._source_type(comp, nm)) for nm in names)
        return rb + ob

    def _use_table(self, comp: Computation) -> Dict[str, List[Instr]]:
        cache = getattr(self, "_uses_cache", None)
        if cache is None:
            self._uses_cache = cache = {}
        sub = cache.get(comp.name)
        if sub is None:
            sub = {}
            for i in comp.instrs:
                for nm in self._operands(i):
                    sub.setdefault(nm, []).append(i)
            cache[comp.name] = sub
        return sub

    def _sink_ratio(self, comp: Computation, ins: Instr) -> float:
        uses = self._use_table(comp).get(ins.name, [])
        if not uses:
            return 1.0
        m = _SHAPE.search(ins.type_str)
        own = _DTYPE_BYTES.get(m.group(1), 4) if m else 4
        worst = 0
        for u in uses:
            if u.op in self._TRANSPARENT or self._is_transparent_fusion(u):
                mu = _SHAPE.search(u.type_str)
                worst = max(worst, _DTYPE_BYTES.get(mu.group(1), 4) if mu else own)
            else:
                return 1.0
        return min(worst / own, 1.0) if own else 1.0

    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape", "transpose")

    def _fusion_bytes(self, sub: Computation, ins: Instr, operand_names,
                      caller: Optional[Computation] = None) -> float:
        """Fusion bytes: per-parameter utilization + (possibly in-place) output.

        Dtype/layout-only ops (convert/bitcast/copy/reshape/transpose) are
        looked through: a TPU compile fuses them into their consumers, so a
        parameter whose only transitive uses are slices contributes its
        sliced bytes, not the full array (the CPU backend sometimes
        materializes convert(whole-stash) -> dus -> convert chains that no
        TPU compile would emit).
        """
        tbl = self._type_table(sub)
        param_list = list(sub.params.keys())
        uses: Dict[str, List[Instr]] = {}
        for i in sub.instrs:
            for nm in self._operands(i):
                uses.setdefault(nm, []).append(i)

        def effective_uses(name, depth=0):
            out = []
            for u in uses.get(name, []):
                if u.op in self._TRANSPARENT and depth < 6:
                    out.extend(effective_uses(u.name, depth + 1))
                else:
                    out.append(u)
            return out

        total = 0.0
        for pi, p in enumerate(param_list):
            full = _shape_bytes(sub.params[p])
            if caller is not None and pi < len(operand_names):
                # TPU-faithful: if the materialized operand came from a
                # transparent (dtype/layout) chain, charge the source dtype
                src = self._source_type(caller, operand_names[pi])
                full = min(full, _shape_bytes(src)) if src else full
            ulist = effective_uses(p)
            if ulist and all(u.op in ("dynamic-slice", "slice",
                                      "dynamic-update-slice") for u in ulist):
                b = 0.0
                for u in ulist:
                    if u.op == "dynamic-update-slice":
                        un = self._operands(u)
                        b += _shape_bytes(tbl.get(un[1], "")) if len(un) > 1 else 0
                    else:
                        b += _shape_bytes(u.type_str)
                total += min(b, full)
            else:
                total += full
        # output: look through transparent root chain; in-place dus writes
        # only the update
        root = next((i for i in sub.instrs if i.is_root),
                    sub.instrs[-1] if sub.instrs else None)
        seen = 0
        while root is not None and root.op in self._TRANSPARENT and seen < 6:
            ops = self._operands(root)
            root = next((i for i in sub.instrs if ops and i.name == ops[0]), None)
            seen += 1
        if root is not None and root.op == "dynamic-update-slice":
            un = self._operands(root)
            total += _shape_bytes(tbl.get(un[1], "")) if len(un) > 1 else 0
        else:
            total += _shape_bytes(ins.type_str)
        return total

    def totals(self) -> CostTotals:
        return self.comp_cost(self.entry)


def analyze_text(text: str, pod_block: Optional[int] = None,
                 fused_attn_shapes=None) -> Dict:
    mc = ModuleCost(text, pod_block, fused_attn_shapes)
    t = mc.totals()
    ici = dcn = 0.0
    per_op: Dict[str, float] = {}
    counts: Dict[str, float] = {}
    for c in t.collectives:
        b = c.bytes_moved * c.count
        per_op[c.op] = per_op.get(c.op, 0.0) + b
        counts[c.op] = counts.get(c.op, 0.0) + c.count
        if c.crosses_pod:
            dcn += b
        else:
            ici += b
    return {
        "flops": t.flops,
        "bytes_accessed": t.bytes,
        "attn_score_bytes": t.attn_score_bytes,
        "collectives": {"ici_bytes": ici, "dcn_bytes": dcn, **per_op},
        "collective_counts": counts,
        "n_collectives": sum(counts.values()),
    }


def f32_hoist_artifact_bytes(text: str) -> float:
    """Estimate of XLA:CPU convert-hoisting artifacts in HBM.

    XLA:CPU's f32-only dots make the compiler hoist whole-buffer bf16->f32
    converts out of while loops: the loop then carries BOTH the bf16 buffer
    and its f32 twin. A TPU compile (native bf16 MXU) never materializes the
    f32 twin. Heuristic: sum f32 while-tuple entries (>=64 MB) whose dims
    match a bf16 while-tuple entry elsewhere in the module.
    """
    import re as _re
    tuples = _re.findall(r"while\(.*?\)", text)
    # collect shapes from all while instruction result types
    whiles = _re.findall(r"= (\([^)]*\)) while\(", text)
    bf16_shapes = set()
    f32_entries = []
    for t in whiles:
        for dt, dims in _SHAPE.findall(t):
            if dt == "bf16":
                bf16_shapes.add(dims)
            elif dt == "f32":
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                if n * 4 >= 64 * 2**20:
                    f32_entries.append((dims, n * 4))
    seen = set()
    total = 0.0
    for dims, b in f32_entries:
        if dims in bf16_shapes and (dims, b) not in seen:
            seen.add((dims, b))
            total += b
    return total
