"""Serving steps: prefill (builds KV caches / recurrent state) and decode
(one new token against a cache of ``seq_len``). Cache sharding comes from the
model's ``cache_axes()`` logical axes; for batch=1 long-context decode the
``kv_seq`` rule is overridden to sequence-shard the cache (context/SP).

``make_decode_step`` fuses sampling into the jitted step so the host loop
syncs once per step for the whole batch (one [B,1] token fetch) instead of
once per slot; ``pos`` may be a [B] vector for continuous batching.
``make_slot_prefill`` prefills a single request into one batch row of the
shared cache while the other rows keep their in-flight state.

Prompt-length bucketing: an exact-length prefill retraces one executable
per distinct prompt length, so production-shaped traffic (every prompt a
different length) turns the engine into a compile loop. ``prefill_buckets``
computes power-of-two bucket edges, ``bucket_for``/``pad_to_bucket``
right-pad a prompt to its bucket edge, and the bucketed step variants take
the *true* length as a traced scalar: logits are gathered at the true last
token and only the real ``[0, len)`` cache positions survive the scatter
(``mask_cache_tail``), so stale pad KV never leaks into later decode.
Compile activity itself is first-class: every engine step goes through
``counting_jit``, whose ``TraceStats`` counts one compile per distinct
abstract input signature — the metric the CI cross-run gate regresses on.
(Signature accounting is wrapper-level and deterministic; ``jax.monitoring``
events would need process-global listeners and are backend-dependent.)
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.tracing import (TraceStats, _abstract_signature,  # noqa: F401
                                counting_jit)
from repro.models.common import (copy_cache_block, gather_cache_slot,
                                 mask_cache_tail, paged_gather,
                                 paged_scatter_block, paged_scatter_slot,
                                 reset_cache_blocks, scatter_cache_slot)
from repro.parallel.sharding import spec_for

# compile accounting (``TraceStats``/``counting_jit``) lives in
# ``repro.core.tracing`` — training and launch meter compiles too — and is
# re-exported here for the serving call sites and existing imports.


# ---------------------------------------------------------------------------
# prompt-length bucketing


def prefill_buckets(max_len: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Power-of-two bucket edges covering prompt lengths in [1, max_len].

    Edges double from ``min_bucket`` and the last edge is clamped to
    ``max_len`` (a prompt can never exceed the cache), so the number of
    distinct prefill shapes — and therefore compiled executables — is
    O(log2(max_len / min_bucket)) regardless of traffic.
    """
    if max_len < 1:
        raise ValueError(f"max_len must be >= 1, got {max_len}")
    edges: List[int] = []
    b = min(min_bucket, max_len)
    while b < max_len:
        edges.append(b)
        b *= 2
    edges.append(min(b, max_len))
    return tuple(edges)


def bucket_for(length: int, buckets: Sequence[int]) -> int:
    """Smallest bucket edge >= length (exact length past the last edge)."""
    for edge in buckets:
        if length <= edge:
            return edge
    return length


def pad_to_bucket(prompt: np.ndarray, buckets: Sequence[int],
                  pad_id: int = 0) -> Tuple[np.ndarray, int]:
    """Right-pad a [S] prompt to its bucket edge; returns (padded, true_len).

    Right-padding (not left) keeps every real token at its true position:
    under causal masking the pad tail cannot influence real positions, so
    bucketed logits at ``true_len - 1`` match the exact-length prefill.
    """
    prompt = np.asarray(prompt, np.int32)
    n = len(prompt)
    edge = bucket_for(n, buckets)
    if edge == n:
        return prompt, n
    padded = np.full(edge, pad_id, np.int32)
    padded[:n] = prompt
    return padded, n


# ---------------------------------------------------------------------------
# step builders


def make_prefill_step(model, bucketed: bool = False):
    """Whole-batch prefill. ``bucketed=True`` adds a traced ``true_len``
    argument: the batch is right-padded to a bucket edge, logits come from
    the true last token, and cache positions >= true_len are zeroed so pad
    KV never reaches decode."""
    if not bucketed:
        def prefill_step(params, batch, caches):
            logits, caches = model.prefill(params, batch, caches)
            return logits, caches
        return prefill_step

    def bucketed_prefill_step(params, batch, true_len, caches):
        logits, caches = model.prefill(params, batch, caches,
                                       true_len=true_len)
        return logits, mask_cache_tail(caches, true_len)
    return bucketed_prefill_step


def make_decode_step(model, greedy=True):
    """Fused decode + in-jit sampling. ``pos``: scalar or [B] int32."""
    def decode_step(params, tokens, pos, caches, key=None):
        logits, caches = model.decode_step(params, tokens, pos, caches)
        if greedy or key is None:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            next_tok = jax.random.categorical(key, logits).astype(jnp.int32)
        return next_tok, logits, caches
    return decode_step


def make_slot_prefill(model, bucketed: bool = False):
    """Prefill one request ([1, S] tokens) into batch row ``slot`` of a
    shared cache pytree; every other row is untouched.

    Exact mode retraces per distinct prompt length (jit caches one
    executable per S). Bucketed mode takes right-padded tokens plus the
    traced true length: executables are bounded by the bucket count, the
    next token comes from the logits at ``true_len - 1``, and only the real
    ``[0, true_len)`` cache positions are scattered back."""
    if not bucketed:
        def slot_prefill(params, tokens, slot, caches):
            sub = gather_cache_slot(caches, slot)
            logits, sub = model.prefill(params, {"tokens": tokens}, sub)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, logits, scatter_cache_slot(caches, sub, slot)
        return slot_prefill

    def bucketed_slot_prefill(params, tokens, true_len, slot, caches):
        sub = gather_cache_slot(caches, slot)
        logits, sub = model.prefill(params, {"tokens": tokens}, sub,
                                    true_len=true_len)
        sub = mask_cache_tail(sub, true_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, scatter_cache_slot(caches, sub, slot)
    return bucketed_slot_prefill


def make_paged_decode_step(model, greedy=True):
    """Fused decode through block-table indirection.

    The pool ([L, P, block, kvh, dh] leaves) is gathered into per-slot
    contiguous views via ``tables`` ([B, NB] block ids), the unmodified
    model decode runs on the view, and only each slot's touched block is
    scattered back. Table *values* are traced, so remaps (prefix sharing,
    COW, lazy growth) never retrace — the decode executable count stays 1.
    """
    def paged_decode_step(params, tokens, pos, tables, pool, key=None):
        view = paged_gather(pool, tables)
        logits, view = model.decode_step(params, tokens, pos, view)
        if greedy or key is None:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            next_tok = jax.random.categorical(key, logits).astype(jnp.int32)
        pool = paged_scatter_block(pool, view, tables, pos)
        return next_tok, logits, pool
    return paged_decode_step


def make_paged_slot_prefill(model, bucketed: bool = False):
    """Prefill one request's *uncached tail* through its block table.

    ``start_pos`` (traced) is the first uncached position: the matched
    prefix blocks already mapped into ``table_row`` supply KV for
    [0, start_pos) with zero compute, the chunk attends causally over
    prefix + itself, and logits come from the chunk's (true) last token.
    Bucketed mode right-pads the tail to its bucket edge; everything at or
    past ``start_pos + true_len`` is zeroed before the scatter so pad KV
    and stale block contents never reach decode. Executables stay bounded
    by the bucket count — the same compile budget as unpaged prefill.
    """
    if not bucketed:
        def paged_slot_prefill(params, tokens, start_pos, table_row, pool):
            sub = paged_gather(pool, table_row[None, :])
            logits, sub = model.prefill(params, {"tokens": tokens}, sub,
                                        start_pos=start_pos)
            sub = mask_cache_tail(sub, start_pos + tokens.shape[1])
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, logits, paged_scatter_slot(pool, sub, table_row)
        return paged_slot_prefill

    def paged_bucketed_slot_prefill(params, tokens, true_len, start_pos,
                                    table_row, pool):
        sub = paged_gather(pool, table_row[None, :])
        logits, sub = model.prefill(params, {"tokens": tokens}, sub,
                                    true_len=true_len, start_pos=start_pos)
        sub = mask_cache_tail(sub, start_pos + true_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, paged_scatter_slot(pool, sub, table_row)
    return paged_bucketed_slot_prefill


def pow2_chunks(n: int) -> List[int]:
    """Decompose a prompt length into power-of-two chunk sizes, largest
    first (its binary representation).

    Chunked left-to-right prefill for the recurrent families feeds these
    through ``model.prefill`` carrying state between chunks: positions stay
    monotone, every chunk size is a power of two (the chunkwise SSM kernels
    require ``t % min(chunk, t) == 0``), and the number of distinct chunk
    shapes over any traffic is <= log2(max_seq) — so the compile count
    stays bounded without ever right-padding carried state.
    """
    if n < 1:
        raise ValueError(f"prompt length must be >= 1, got {n}")
    return [1 << b for b in range(n.bit_length() - 1, -1, -1) if n & (1 << b)]


def make_recurrent_chunk_prefill(model):
    """One chunk of a left-to-right recurrent prefill.

    ``state`` is the batch-1 carried state tree (fresh on the first chunk);
    ``start_pos`` (traced) is the chunk's absolute offset — position-free
    families ignore it, attention-bearing recurrent families (zamba2 shared
    attention, whisper decoder self-attention) offset their KV writes and
    masks with it. ``frames`` is None except on an audio request's first
    chunk, where it feeds the encoder and fills the cross cache that later
    chunks (and decode) reuse; the None/array pytree difference gives the
    frames variant its own executable, counted like any other.

    Returns ``(next_token, logits, state)`` with the next token sampled
    from the chunk's last position — after the final chunk that is the
    request's first generated token.
    """
    def chunk_prefill(params, tokens, frames, start_pos, state):
        batch = {"tokens": tokens}
        if frames is not None:
            batch["frames"] = frames
        logits, state = model.prefill(params, batch, state,
                                      start_pos=start_pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, state
    return chunk_prefill


def make_block_ops(stats: Optional[TraceStats] = None, on_compile=None):
    """Jitted pool maintenance ops: (zero_blocks, copy_block).

    ``zero_blocks(pool, blocks)`` scrubs freed blocks (fixed-width padded
    id vector -> one executable); ``copy_block(pool, src, dst)`` is the
    copy-on-write arm (traced scalars -> one executable). Both run under
    ``counting_jit`` so the engine's ``TraceStats`` — and the CI compile
    gate — see the pool-maintenance executables, not just prefill/decode."""
    return (counting_jit(reset_cache_blocks, "zero_blocks", stats,
                         on_compile=on_compile),
            counting_jit(copy_cache_block, "copy_block", stats,
                         on_compile=on_compile))


def serve_rules(shape):
    """Sharding-rule overrides per shape cell.

    batch=1 (long_500k): nothing to shard on batch -> sequence-shard KV
    caches over ("pod","data") and keep TP on heads.
    """
    if shape.global_batch == 1:
        return {"batch": None, "kv_seq": ("pod", "data")}
    return {}


def cache_specs(mesh, model, cache_sds, rules=None):
    axes = model.cache_axes()
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda a, c: spec_for(mesh, a, c.shape, rules),
        axes, cache_sds, is_leaf=is_axes)


def abstract_cache(model, batch_size, max_seq, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model.init_cache(batch_size, max_seq, dtype))
