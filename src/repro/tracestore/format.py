"""The ``.dkt`` binary trace format (DALEK trace, version 1).

A trace file persists the telemetry platform's columnar ``SampleBlock``
streams bit-exactly, so a recorded run can be reloaded and replayed
offline with the same energy numbers the live session produced.

File layout (all integers little-endian)::

    header   := b"DKTR" u32:version
    chunk*   := chunk_header chunk_payload          (append-only)
    footer   := json (streams, tags, chunk index, user meta)
    trailer  := u64:footer_nbytes b"DKTE"

One chunk holds one ``SampleBlock`` — recorders append one chunk per
sampling window, so window boundaries survive the round trip (replay needs
them to re-drive sessions window by window). Chunk payloads are raw numpy
columns::

    chunk_header  := u32:stream_id u32:n_segs u64:n u64:n_map u32:n_avg
    chunk_payload := f64 t[n] | f64 volts[n] | f64 watts[n] | f64 dt[n]
                     u8 bits[n] | i64 seg_bounds[n_segs+1]
                     u32 seg_entry_counts[n_segs]
                     u8 entry_lines[n_map] | u32 entry_tag_ids[n_map]

Tag names are interned once per file in the footer's ``tags`` table;
segment maps store (gpio line, tag id) pairs, so recycled GPIO channels
(any number of distinct names over a run) cost 5 bytes per mapping entry
instead of a string copy per segment. The footer's chunk index rows
``[stream_id, offset, nbytes, n, t0, t1]`` give O(log chunks) time seeks
without touching the payload bytes, and decoding builds numpy views
directly over the file buffer (mmap-friendly: nothing is copied until a
reduction runs).
"""
from __future__ import annotations

import dataclasses
import json
import struct
from typing import Callable, Dict, List, Mapping, Tuple

import numpy as np

from repro.telemetry.samples import SampleBlock

MAGIC = b"DKTR"
END_MAGIC = b"DKTE"
VERSION = 1

HEADER = struct.Struct("<4sI")            # magic, version
CHUNK_HDR = struct.Struct("<IIQQI")       # stream_id, n_segs, n, n_map, n_avg
TRAILER = struct.Struct("<Q4s")           # footer_nbytes, end magic


class TraceFormatError(ValueError):
    """The bytes are not a readable ``.dkt`` trace (bad magic, truncated
    file, or an unsupported version)."""


def encode_header() -> bytes:
    return HEADER.pack(MAGIC, VERSION)


def decode_header(buf: bytes) -> int:
    """Validate the leading magic and return the format version."""
    if len(buf) < HEADER.size:
        raise TraceFormatError(f"file too short for a .dkt header "
                               f"({len(buf)} bytes)")
    magic, version = HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise TraceFormatError(f"bad magic {magic!r} (expected {MAGIC!r})")
    if version != VERSION:
        raise TraceFormatError(f"unsupported .dkt version {version} "
                               f"(this reader speaks {VERSION})")
    return version


@dataclasses.dataclass(frozen=True)
class ChunkInfo:
    """One chunk-index row from the footer."""

    stream_id: int
    offset: int
    nbytes: int
    n: int
    t0: float            # first report timestamp (0.0 when empty)
    t1: float            # last report timestamp (0.0 when empty)

    def row(self) -> list:
        return [self.stream_id, self.offset, self.nbytes, self.n,
                self.t0, self.t1]

    @classmethod
    def from_row(cls, row) -> "ChunkInfo":
        return cls(int(row[0]), int(row[1]), int(row[2]), int(row[3]),
                   float(row[4]), float(row[5]))


def encode_chunk(stream_id: int, block: SampleBlock,
                 intern_tag: Callable[[str], int]) -> bytes:
    """Serialize one ``SampleBlock`` as a chunk. ``intern_tag`` maps a tag
    name to its id in the file's tag table (appending on first use)."""
    n = block.n
    n_segs = len(block.seg_maps)
    lines: List[int] = []
    ids: List[int] = []
    counts = np.zeros(n_segs, "<u4")
    for k, m in enumerate(block.seg_maps):
        counts[k] = len(m)
        for line, name in m.items():
            lines.append(line)
            ids.append(intern_tag(name))
    n_map = len(lines)
    parts = [
        CHUNK_HDR.pack(stream_id, n_segs, n, n_map, block.n_avg),
        np.ascontiguousarray(block.t, "<f8").tobytes(),
        np.ascontiguousarray(block.volts, "<f8").tobytes(),
        np.ascontiguousarray(block.watts, "<f8").tobytes(),
        np.ascontiguousarray(block.dt, "<f8").tobytes(),
        np.ascontiguousarray(block.bits, "u1").tobytes(),
        np.ascontiguousarray(block.seg_bounds, "<i8").tobytes(),
        counts.tobytes(),
        np.asarray(lines, "u1").tobytes(),
        np.asarray(ids, "<u4").tobytes(),
    ]
    return b"".join(parts)


def decode_chunk(buf, offset: int,
                 tags: List[str]) -> Tuple[int, SampleBlock, int]:
    """Decode the chunk at ``offset``; returns (stream_id, block, end).

    Columns are numpy views over ``buf`` (zero-copy when ``buf`` is a
    mmap), so streaming a large trace only faults the pages a reduction
    actually touches.
    """
    try:
        stream_id, n_segs, n, n_map, n_avg = CHUNK_HDR.unpack_from(buf, offset)
    except struct.error as e:
        raise TraceFormatError(f"truncated chunk header at {offset}") from e
    off = offset + CHUNK_HDR.size

    def take(dtype, count):
        nonlocal off
        arr = np.frombuffer(buf, dtype=dtype, count=count, offset=off)
        off += arr.nbytes
        return arr

    t = take("<f8", n)
    volts = take("<f8", n)
    watts = take("<f8", n)
    dt = take("<f8", n)
    bits = take("u1", n)
    seg_bounds = take("<i8", n_segs + 1)    # always n_segs+1 (1 when empty)
    counts = take("<u4", n_segs)
    lines = take("u1", n_map)
    ids = take("<u4", n_map)
    maps: List[Mapping[int, str]] = []
    pos = 0
    for k in range(n_segs):
        c = int(counts[k])
        maps.append({int(lines[pos + j]): tags[int(ids[pos + j])]
                     for j in range(c)})
        pos += c
    block = SampleBlock(t=t, volts=volts, watts=watts, dt=dt, bits=bits,
                        seg_bounds=np.asarray(seg_bounds, np.int64),
                        seg_maps=tuple(maps), n_avg=int(n_avg))
    return stream_id, block, off


def chunk_info(stream_id: int, offset: int, nbytes: int,
               block: SampleBlock) -> ChunkInfo:
    return ChunkInfo(stream_id, offset, nbytes, block.n,
                     float(block.t[0]) if block.n else 0.0,
                     float(block.t[-1]) if block.n else 0.0)


def encode_footer(streams: List[Dict], tags: List[str],
                  chunks: List[ChunkInfo], meta: Dict) -> bytes:
    doc = {"version": VERSION, "streams": streams, "tags": tags,
           "chunks": [c.row() for c in chunks], "meta": meta}
    payload = json.dumps(doc).encode("utf-8")
    return payload + TRAILER.pack(len(payload), END_MAGIC)


def decode_footer(buf) -> Dict:
    """Parse the footer from the tail of a full file buffer."""
    if len(buf) < HEADER.size + TRAILER.size:
        raise TraceFormatError("file too short for a .dkt trailer")
    nbytes, end = TRAILER.unpack_from(buf, len(buf) - TRAILER.size)
    if end != END_MAGIC:
        raise TraceFormatError(
            f"bad end magic {end!r} — file truncated or not closed")
    start = len(buf) - TRAILER.size - nbytes
    if start < HEADER.size:
        raise TraceFormatError("footer length exceeds file size")
    doc = json.loads(bytes(buf[start:start + nbytes]).decode("utf-8"))
    if doc.get("version") != VERSION:
        raise TraceFormatError(f"unsupported footer version "
                               f"{doc.get('version')}")
    return doc
