"""whisper-small — encoder-decoder audio backbone, conv frontend STUB
[arXiv:2212.04356; unverified]. input_specs() provides precomputed frame
embeddings for the encoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    encoder_decoder=True, enc_layers=12, enc_seq=1500,
    frontend_stub=True,
    source="arXiv:2212.04356",
)

SMOKE_CONFIG = CONFIG.replace(
    name="whisper-small-smoke", num_layers=2, enc_layers=2, d_model=128,
    num_heads=8, num_kv_heads=8, d_ff=256, vocab_size=512, head_dim=16,
    enc_seq=64,
)
