"""Roofline model for TPU v5e: three terms from the compiled dry-run.

    compute term    = HLO_FLOPs / (peak FLOP/s per chip)
    memory term     = HLO_bytes / (HBM bandwidth per chip)
    collective term = ici_bytes / ici_bw + dcn_bytes / dcn_bw

All quantities are per-device (cost_analysis is post-SPMD). The dominant term
is the bottleneck; MODEL_FLOPS / HLO_FLOPs measures how much compiled compute
is "useful" (catches remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (use 1 link conservatively)
DCN_BW = 25e9                     # B/s inter-pod (slow axis; 2.5GbE analogue,
                                  # scaled to datacenter DCN)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float

    def to_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg, shape, n_params_active: float) -> float:
    """6·N·D for training; 2·N·D for inference (per forward token)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_params_active * tokens


def count_params(params_sds) -> float:
    import jax
    return float(sum(
        __import__("numpy").prod(p.shape) for p in jax.tree.leaves(params_sds)))


def active_params(cfg, n_total: float) -> float:
    """MoE: only top-k + shared experts are active per token."""
    if not cfg.is_moe:
        return n_total
    routed_per_layer = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts
    n_moe_layers = cfg.num_layers - cfg.first_k_dense
    inactive = routed_per_layer * n_moe_layers * (
        1 - cfg.experts_per_token / cfg.num_experts)
    return n_total - inactive


def compute_roofline(analysis: Dict, n_chips: int, model_fl: float) -> Roofline:
    flops = analysis["flops"]
    bytes_hbm = analysis["bytes_accessed"]
    coll = analysis["collectives"]
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_hbm / HBM_BW
    collective_s = (coll.get("ici_bytes", 0.0) / ICI_BW
                    + coll.get("dcn_bytes", 0.0) / DCN_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    per_dev_model_flops = model_fl / n_chips
    # training backward ~2x forward FLOPs is already in the 6x multiplier
    useful = per_dev_model_flops / flops if flops else 0.0
    return Roofline(compute_s, memory_s, collective_s, dominant,
                    model_fl, flops, useful)
