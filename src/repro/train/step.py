"""Train step: bf16 compute, fp32 master params/optimizer, microbatched
gradient accumulation (lax.scan), remat, and sharding-spec construction for
pjit. The ``pod`` axis carries pure data parallelism — the slow-network axis,
per DALEK's design; see ``repro.parallel.compress`` for the compressed
variant of the cross-pod gradient reduction."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import softmax_xent
from repro.parallel.sharding import spec_for, tree_specs
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig, OptState


class TrainState(NamedTuple):
    params: dict
    opt: OptState


@dataclasses.dataclass(frozen=True)
class StepConfig:
    num_microbatches: int = 1
    aux_loss_weight: float = 0.01
    label_ignore: int = -1
    # cast fp32 master params to bf16 ONCE per step (outside the microbatch
    # accumulation loop): FSDP gathers move bf16 instead of f32, and XLA can
    # hoist the gather out of the loop. Grads are computed w.r.t. the bf16
    # tree and accumulated in f32 (standard bf16-param/f32-master scheme).
    cast_params_once: bool = False
    # >1: chunked cross-entropy (never materializes [B,S,V] logits);
    # requires the model to expose .hidden()
    vocab_chunks: int = 1


def make_loss_fn(model, step_cfg: StepConfig):
    if step_cfg.vocab_chunks > 1 and hasattr(model, "hidden"):
        from repro.models.common import chunked_softmax_xent

        def loss_fn(params, mb):
            h, aux = model.hidden(params, mb)
            labels = mb["labels"]
            h = h[:, -labels.shape[1]:]
            mask = (labels != step_cfg.label_ignore).astype(jnp.float32)
            loss = chunked_softmax_xent(h, params, jnp.maximum(labels, 0),
                                        mask, step_cfg.vocab_chunks)
            return loss + step_cfg.aux_loss_weight * aux
        return loss_fn

    def loss_fn(params, mb):
        logits, aux = model.forward(params, mb)
        labels = mb["labels"]
        logits = logits[:, -labels.shape[1]:]
        mask = (labels != step_cfg.label_ignore).astype(jnp.float32)
        loss = softmax_xent(logits, jnp.maximum(labels, 0), mask)
        return loss + step_cfg.aux_loss_weight * aux
    return loss_fn


def make_train_step(model, opt_cfg: OptConfig, step_cfg: StepConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(model, step_cfg)
    n_micro = step_cfg.num_microbatches

    def train_step(state: TrainState, batch):
        params = state.params
        if step_cfg.cast_params_once:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
            # barrier pins the cast BEFORE the FSDP all-gather: the gather
            # moves bf16, not the f32 the CPU backend's promoted dots want
            params = jax.lax.optimization_barrier(params)

        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                batch)
            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gacc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            (grads, loss), _ = lax.scan(body, (gzero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro

        new_params, new_opt, metrics = opt_mod.adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt), metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding-spec construction for pjit


def batch_specs(mesh, batch_sds, rules=None):
    """Shard the leading (batch) dim of every input over ("pod","data")."""
    def spec(x):
        return spec_for(mesh, ("batch",) + (None,) * (len(x.shape) - 1),
                        x.shape, rules)
    return jax.tree.map(spec, batch_sds)


def param_specs(mesh, params_sds, axes, rules=None):
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda a, p: spec_for(mesh, a, p.shape, rules),
        axes, params_sds, is_leaf=is_axes)


def state_specs(mesh, params_sds, axes, rules=None):
    ps = param_specs(mesh, params_sds, axes, rules)
    return TrainState(params=ps, opt=OptState(m=ps, v=ps, step=P()))


def shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
