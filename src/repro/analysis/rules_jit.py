"""jit-hygiene rules: DLK001 bare-jit, DLK003 traced-value-branch,
DLK004 jit-kwargs-hygiene."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, ModuleContext, Rule, is_counting_jit,
                                 is_jax_jit, is_partial_jit, literal_ints,
                                 literal_names, qualname, register, root_name)


@register
class BareJit(Rule):
    """Any ``jax.jit`` reference outside ``counting_jit``.

    PR 4 made compile counts a regression-gated serving metric; an
    executable created by a bare ``jax.jit`` never reaches a ``TraceStats``,
    so its (re)compiles are invisible to the run stats, the telemetry
    counters, and the CI gate. Wrap it in ``repro.core.tracing.counting_jit``
    or justify it with ``# dalek: allow[bare-jit]``.

    Skips test files: tests jit fresh reference computations by design and
    have no compile budget to meter.
    """

    code = "DLK001"
    name = "bare-jit"
    skip_tests = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, (ast.Attribute, ast.Name))
                    and is_jax_jit(node, ctx)):
                continue
            fn = ctx.enclosing_function(node)
            if fn is not None and fn.name == "counting_jit":
                continue    # the one sanctioned wrapper
            yield ctx.finding(
                self, node,
                "bare jax.jit: executable is invisible to TraceStats and "
                "the CI compile gate — use counting_jit (repro.core.tracing)")


def _jit_bodies(ctx: ModuleContext) -> List[Tuple[ast.FunctionDef, Set[str]]]:
    """(function def, static param names) for every function whose body
    runs under trace: decorated with jax.jit / partial(jax.jit, ...),
    passed by name to jax.jit/counting_jit, or an inner def returned by a
    ``make_*`` step factory (this repo's step-builder convention)."""
    defs: Dict[str, ast.FunctionDef] = {}
    for fn in ctx.functions:
        defs.setdefault(fn.name, fn)
    bodies: Dict[int, Tuple[ast.FunctionDef, Set[str]]] = {}

    def static_names(call: Optional[ast.Call]) -> Set[str]:
        out: Set[str] = set()
        if call is None:
            return out
        nums: List[int] = []
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                out |= set(literal_names(kw.value))
            elif kw.arg == "static_argnums":
                nums = literal_ints(kw.value)
        if nums:
            # resolve indices against the jitted fn's own params
            fn_arg = None
            if call.args and not is_jax_jit(call.args[0], ctx):
                fn_arg = call.args[0]
            elif len(call.args) > 1:
                fn_arg = call.args[1]
            if isinstance(fn_arg, ast.Name) and fn_arg.id in defs:
                params = [a.arg for a in defs[fn_arg.id].args.args]
                out |= {params[i] for i in nums if 0 <= i < len(params)}
        return out

    def add(fn: ast.FunctionDef, statics: Set[str]):
        bodies.setdefault(id(fn), (fn, statics))

    for fn in ctx.functions:
        for dec in fn.decorator_list:
            if is_jax_jit(dec, ctx):
                add(fn, set())
            elif is_partial_jit(dec, ctx):
                add(fn, static_names(dec))
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if is_jax_jit(node.func, ctx) or is_counting_jit(node.func):
            if node.args and isinstance(node.args[0], ast.Name) \
                    and node.args[0].id in defs:
                add(defs[node.args[0].id], static_names(node))
    for fn in ctx.functions:
        if not fn.name.startswith("make_"):
            continue
        inner = {n.name: n for n in fn.body
                 if isinstance(n, ast.FunctionDef)}
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and isinstance(node.value,
                                                           ast.Name):
                if node.value.id in inner:
                    add(inner[node.value.id], set())
    return list(bodies.values())


def _concretizing_names(test: ast.AST) -> Set[str]:
    """Names whose *value* the test would force to a concrete bool —
    excluding trace-safe uses: ``is``/``is not`` comparisons, len()/
    isinstance()-style introspection, and .shape/.dtype/.ndim/.size
    access (all static under tracing)."""
    out: Set[str] = set()
    SAFE_CALLS = {"len", "isinstance", "hasattr", "getattr", "type",
                  "callable"}
    SAFE_ATTRS = {"shape", "dtype", "ndim", "size"}

    def walk(node):
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in SAFE_CALLS:
            return
        if isinstance(node, ast.Attribute) and node.attr in SAFE_ATTRS:
            return
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            walk(child)

    walk(test)
    return out


@register
class TracedValueBranch(Rule):
    """Python ``if``/``while``/``assert`` on a traced value inside a jitted
    body: concretizes the tracer (ConcretizationTypeError) or, with
    static_argnums, silently retraces per value."""

    code = "DLK003"
    name = "traced-branch"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn, statics in _jit_bodies(ctx):
            tainted = {a.arg for a in fn.args.args
                       + fn.args.posonlyargs + fn.args.kwonlyargs
                       if a.arg not in statics and a.arg != "self"}
            inner_fns = {id(f) for f in ast.walk(fn)
                         if isinstance(f, (ast.FunctionDef, ast.Lambda))
                         and f is not fn}
            for node in ast.walk(fn):
                # taint flows through plain assignments
                if isinstance(node, ast.Assign):
                    used = {n.id for n in ast.walk(node.value)
                            if isinstance(n, ast.Name)
                            and isinstance(n.ctx, ast.Load)}
                    if used & tainted:
                        for tgt in node.targets:
                            for t in (tgt.elts if isinstance(tgt, ast.Tuple)
                                      else [tgt]):
                                if isinstance(t, ast.Name):
                                    tainted.add(t.id)
                if not isinstance(node, (ast.If, ast.While, ast.Assert,
                                         ast.IfExp)):
                    continue
                if any(id(a) in inner_fns for a in ctx.ancestors(node)):
                    continue    # nested defs have their own params/trace
                hits = _concretizing_names(node.test) & tainted
                if hits:
                    kind = type(node).__name__.lower()
                    yield ctx.finding(
                        self, node,
                        f"python {kind} on traced value "
                        f"({', '.join(sorted(hits))}) inside jitted body "
                        f"'{fn.name}' — ConcretizationError/retrace hazard")


@register
class JitKwargsHygiene(Rule):
    """Suspicious ``static_argnums``/``donate_argnums`` wiring: indices out
    of range, static/donate overlap, unknown argnames, statics that look
    like arrays (unhashable -> TypeError, or a retrace per value), and
    donated buffers read again after the donating call."""

    code = "DLK004"
    name = "jit-kwargs"

    ARRAYISH_ATTRS = {"shape", "dtype", "astype", "at", "T", "ndim"}

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        defs = {}
        for fn in ctx.functions:
            defs.setdefault(fn.name, fn)
        donating: Dict[str, List[int]] = {}

        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            jit_call = is_jax_jit(node.func, ctx) or is_counting_jit(node.func)
            if not (jit_call or is_partial_jit(node, ctx)):
                continue
            kwargs = {kw.arg: kw.value for kw in node.keywords if kw.arg}
            statics = literal_ints(kwargs.get("static_argnums", ast.Tuple(elts=[])))
            donated = literal_ints(kwargs.get("donate_argnums", ast.Tuple(elts=[])))
            snames = literal_names(kwargs.get("static_argnames", ast.Tuple(elts=[])))
            dnames = literal_names(kwargs.get("donate_argnames", ast.Tuple(elts=[])))
            if not (statics or donated or snames or dnames):
                continue

            overlap = sorted(set(statics) & set(donated))
            if overlap:
                yield ctx.finding(
                    self, node,
                    f"argnums {overlap} are both static and donated — a "
                    "static arg is hashed, not a buffer; it cannot be "
                    "donated")
            overlap_n = sorted(set(snames) & set(dnames))
            if overlap_n:
                yield ctx.finding(
                    self, node,
                    f"argnames {overlap_n} are both static and donated")

            # resolve the wrapped function for arity/param checks
            fn_node: Optional[ast.FunctionDef] = None
            target = None
            if jit_call and node.args:
                target = node.args[0]
            if isinstance(target, ast.Name) and target.id in defs:
                fn_node = defs[target.id]
            elif isinstance(target, ast.Lambda):
                fn_node = target
            if fn_node is None:
                continue
            params = [a.arg for a in fn_node.args.args]
            has_varargs = fn_node.args.vararg is not None
            for idx in set(statics + donated):
                if idx >= len(params) and not has_varargs:
                    which = "static" if idx in statics else "donate"
                    yield ctx.finding(
                        self, node,
                        f"{which}_argnums index {idx} out of range for "
                        f"'{getattr(fn_node, 'name', '<lambda>')}' "
                        f"({len(params)} positional params)")
            known = set(params) | {a.arg for a in fn_node.args.kwonlyargs}
            if fn_node.args.kwarg is None:
                for nm in set(snames + dnames):
                    if nm not in known:
                        yield ctx.finding(
                            self, node,
                            f"argname '{nm}' not a parameter of "
                            f"'{getattr(fn_node, 'name', '<lambda>')}'")
            # array-shaped statics: a param used like an array must be traced
            static_params = {params[i] for i in statics
                             if 0 <= i < len(params)} | set(snames)
            if static_params and isinstance(fn_node, ast.FunctionDef):
                for sub in ast.walk(fn_node):
                    if isinstance(sub, ast.Attribute) \
                            and sub.attr in self.ARRAYISH_ATTRS \
                            and isinstance(sub.value, ast.Name) \
                            and sub.value.id in static_params:
                        yield ctx.finding(
                            self, sub,
                            f"static param '{sub.value.id}' of "
                            f"'{fn_node.name}' is used like an array "
                            f"(.{sub.attr}) — static arrays are unhashable "
                            "or retrace per value")

            # remember jitted names that donate, for the call-site check
            parent = ctx.parent(node)
            if donated and isinstance(parent, ast.Assign):
                for tgt in parent.targets:
                    if isinstance(tgt, ast.Name):
                        donating[tgt.id] = donated

        # call-site check: a donated buffer read after the donating call is
        # use-after-donate (jax warns at runtime; here it's caught statically)
        for name, idxs in donating.items():
            for node in ast.walk(ctx.tree):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id == name):
                    continue
                fn = ctx.enclosing_function(node)
                if fn is None:
                    continue
                stmt = node
                while ctx.parent(stmt) is not fn and ctx.parent(stmt) is not None:
                    stmt = ctx.parent(stmt)
                rebound: Set[str] = set()
                if isinstance(stmt, ast.Assign):
                    for tgt in stmt.targets:
                        for t in (tgt.elts if isinstance(tgt, ast.Tuple)
                                  else [tgt]):
                            if isinstance(t, ast.Name):
                                rebound.add(t.id)
                for idx in idxs:
                    if idx >= len(node.args):
                        continue
                    arg = node.args[idx]
                    if not isinstance(arg, ast.Name) or arg.id in rebound:
                        continue
                    for later in ast.walk(fn):
                        if isinstance(later, ast.Name) \
                                and later.id == arg.id \
                                and isinstance(later.ctx, ast.Load) \
                                and later.lineno > node.end_lineno:
                            yield ctx.finding(
                                self, later,
                                f"'{arg.id}' was donated to '{name}' "
                                f"(line {node.lineno}) and read again — "
                                "use-after-donate")
                            break
