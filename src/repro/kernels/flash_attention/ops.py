"""Jit'd wrapper for the flash attention kernel."""
from repro.core.tracing import TraceStats, counting_jit
from repro.kernels.flash_attention.flash_attention import flash_attention

#: module-level compile accounting for the jitted entry point
stats = TraceStats()


def _attention(q, k, v, causal=True, window=None, block_q=128, block_kv=128,
               interpret=False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_kv=block_kv,
                           interpret=interpret)


attention = counting_jit(_attention, "flash/attention", stats,
                         static_argnames=("causal", "window", "block_q",
                                          "block_kv", "interpret"))
