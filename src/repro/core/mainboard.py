"""Main-board aggregator (paper Sec. 4.1).

One PIC18-based board per node: two I2C connectors, up to six probes
daisy-chained per connector (12 max), 5 V USB power + data. The I2C bus is
the bottleneck: with six probes on one bus the system sustains at most
1000 SPS *per probe report stream*; oversubscription degrades the per-probe
rate proportionally. Eight GPIO inputs tag samples with code regions.

We model the board faithfully: bus budget enforcement, per-probe report
streams, tag annotation at sample timestamps, and a host-side API
(``read_samples``) mirroring the planned C API (paper Sec. 4.3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.probe import REPORT_SPS, Probe, Sample
from repro.core.tags import TagBus

N_I2C_BUSES = 2
PROBES_PER_BUS = 6
MAX_PROBES = N_I2C_BUSES * PROBES_PER_BUS
BUS_MAX_SPS = PROBES_PER_BUS * REPORT_SPS   # paper: 1000 SPS with 6 probes


class MainBoard:
    """Aggregates up to 12 probes; attaches GPIO tags to samples."""

    def __init__(self, node_name: str = "node", clock_t0: float = 0.0):
        self.node_name = node_name
        self._buses: List[List[Probe]] = [[], []]
        self._tags = TagBus(clock=self._now)
        self._t = clock_t0

    # -- virtual clock (simulation time) ------------------------------------

    def _now(self) -> float:
        return self._t

    def advance(self, dt: float):
        self._t += dt

    @property
    def tags(self) -> TagBus:
        return self._tags

    # -- probe management ----------------------------------------------------

    def attach(self, probe: Probe, bus: Optional[int] = None) -> int:
        if bus is None:
            bus = 0 if len(self._buses[0]) <= len(self._buses[1]) else 1
        if not 0 <= bus < N_I2C_BUSES:
            raise ValueError(f"bus {bus} out of range")
        if len(self._buses[bus]) >= PROBES_PER_BUS:
            raise RuntimeError(
                f"I2C bus {bus} full ({PROBES_PER_BUS} probes max — paper HW limit)")
        self._buses[bus].append(probe)
        return bus

    @property
    def n_probes(self) -> int:
        return sum(len(b) for b in self._buses)

    def effective_sps(self, bus: int) -> float:
        """Per-probe report rate on a bus (I2C budget shared)."""
        n = len(self._buses[bus])
        if n == 0:
            return 0.0
        return min(REPORT_SPS, BUS_MAX_SPS / n)

    # -- sampling ------------------------------------------------------------

    def read_samples(self, duration: float) -> Dict[int, List[Sample]]:
        """Advance time by ``duration`` and return per-probe samples with
        the GPIO tags that were active at each sample timestamp."""
        t0 = self._t
        out: Dict[int, List[Sample]] = {}
        pid = 0
        for bus in self._buses:
            for probe in bus:
                samples = probe.read(t0, duration)
                tagged = [dataclasses.replace(s, tags=self._tags.active_at(s.t))
                          for s in samples]
                out[pid] = tagged
                pid += 1
        self._t = t0 + duration
        return out

    # -- energy accounting ---------------------------------------------------

    @staticmethod
    def energy_j(samples: List[Sample]) -> float:
        """Trapezoid-free: samples are averaged power over fixed intervals."""
        if not samples:
            return 0.0
        dt = 1.0 / REPORT_SPS
        return sum(s.watts for s in samples) * dt

    @staticmethod
    def energy_by_tag(samples: List[Sample]) -> Dict[str, float]:
        """Per-tag energy attribution (paper Sec. 4.1: GPIO-synchronized
        fine-grained profiling)."""
        dt = 1.0 / REPORT_SPS
        out: Dict[str, float] = {}
        for s in samples:
            for tag in s.tags:
                out[tag] = out.get(tag, 0.0) + s.watts * dt
        return out
