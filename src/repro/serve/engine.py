"""Serving engines with energy-attributed telemetry.

Two engines share one telemetry pipeline (a ``repro.telemetry``
``MonitorSession`` over the paper Sec. 4.1 probe/board/tag-bus platform),
with power traces *derived* from the roofline/DVFS energy model
(``core.energy.ServePowerModel``) — no hardcoded watt constants:

``ServeEngine``      static-batch baseline: one padded prefill, lock-step
                     decode until every request in the batch finishes.
``ContinuousEngine`` true continuous batching: admission-controlled request
                     queue, per-slot KV-cache state, fused jitted decode with
                     per-slot positions (one host sync per step), slot
                     recycling so new requests join mid-decode, per-request
                     J/token attribution via GPIO slot tags, and an
                     energy-aware admission policy (DVFS power capping +
                     TTL shedding from measured throughput).

Both engines bucket prefill lengths by default (``prefill_buckets="auto"``:
power-of-two edges up to ``max_seq``): prompts are right-padded to the
bucket edge so the number of compiled prefill executables is bounded by the
bucket count instead of growing with every distinct prompt length. Every
jitted step runs through ``serve.step.counting_jit``; compile counts are
exposed in the run stats (``prefill_compiles``/``decode_compiles``), as
telemetry counters on the ``MonitorSession`` report, and regression-gated
in CI — unbounded compilation silently dominates the J/token numbers the
platform exists to measure.
"""
from __future__ import annotations

import contextlib
import inspect
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import ServePowerModel
from repro.core.hw import DeviceSpec, TPU_V5E
from repro.core.scheduler import ThroughputStats
from repro.core.tags import N_GPIO
from repro.models.common import reset_cache_slot
from repro.serve.queue import AdmissionController, Request, RequestQueue
from repro.serve.slots import SlotManager
from repro.serve.step import (TraceStats, bucket_for, counting_jit,
                              make_decode_step, make_prefill_step,
                              make_slot_prefill, pad_to_bucket)
from repro.serve.step import prefill_buckets as auto_prefill_buckets
from repro.telemetry import ModelSource, MonitorSession

__all__ = ["Request", "ServeEngine", "ContinuousEngine", "EngineTelemetry"]


def supports_bucketed_prefill(model) -> bool:
    """True when ``model.prefill`` accepts the ``true_len`` kwarg.

    The transformer families (dense/MoE/VLM, gemma3 windows) do; the
    recurrent-state families (SSM/hybrid, whisper) prefill sequentially and
    cannot right-pad — a pad tail would corrupt the carried state."""
    try:
        sig = inspect.signature(model.prefill)
    except (TypeError, ValueError):
        return False
    return "true_len" in sig.parameters


def resolve_buckets(spec, max_seq: int, model=None):
    """Normalize a ``prefill_buckets`` argument.

    ``"auto"``/True -> power-of-two edges up to ``max_seq``; ``None``/
    ``"off"``/False -> bucketing disabled (exact-length prefill, one
    executable per distinct length); an iterable -> explicit edges (sorted,
    deduped, capped at ``max_seq``). With a ``model``, ``"auto"`` silently
    degrades to off when the model cannot prefill under right-pad
    (``supports_bucketed_prefill``); explicitly requested edges raise."""
    if spec in (None, False, "off", "none"):
        return None
    supported = model is None or supports_bucketed_prefill(model)
    if spec in (True, "auto"):
        return auto_prefill_buckets(max_seq) if supported else None
    if not supported:
        raise ValueError(
            f"{type(model).__name__}.prefill takes no true_len: this family "
            "cannot use length-bucketed prefill (pass prefill_buckets='off')")
    edges = sorted({min(int(b), max_seq) for b in spec if int(b) >= 1})
    if not edges:
        raise ValueError(f"no usable prefill buckets in {spec!r}")
    if edges[-1] < max_seq:
        edges.append(max_seq)     # every admissible prompt must fit a bucket
    return tuple(edges)


def _count_params(params) -> float:
    return float(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params)))


def _cache_bytes(model, batch_size, max_seq) -> float:
    """KV-cache footprint (bytes) without allocating it."""
    sds = jax.eval_shape(lambda: model.init_cache(batch_size, max_seq))
    return float(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                     for l in jax.tree.leaves(sds)))


class EngineTelemetry:
    """Engine-side policy over a ``repro.telemetry`` ``MonitorSession``.

    Phase tags ("prefill"/"decode") use two GPIO channels; the remaining
    channels carry per-slot tags so board energy can be attributed to the
    request owning each slot. With more slots than spare channels, slots
    share tags round-robin and a shared tag's energy splits equally among
    its active slots (board power is one stream; concurrent attribution
    needs a split policy — we use equal shares).
    """

    N_PHASE_TAGS = 2

    def __init__(self, power_model: ServePowerModel, batch_size: int,
                 node: str = "serve-node"):
        self.pm = power_model
        self.source = ModelSource(power_model)
        self.session = MonitorSession(self.source, node=node)
        self.n_slot_tags = max(1, min(batch_size, N_GPIO - self.N_PHASE_TAGS))
        # per-window event log: what replay needs to re-drive this session
        # deterministically against a recorded trace (repro.tracestore)
        self.events: List[Dict] = []

    def slot_tag(self, slot_index: int) -> str:
        return f"s{slot_index % self.n_slot_tags}"

    def record(self, phase: str, wall_s: float, n_tokens: int,
               slot_to_req: Dict[int, Request]):
        """Sample ``wall_s`` of board power under ``phase`` + slot tags and
        attribute each sample's energy to the requests owning the slots
        (vectorized bitmask share computation on the columnar block).

        ``session.sample`` keeps windows on the global 1-kHz grid, so
        sub-millisecond steps carry their fraction into the next window
        instead of silently dropping energy."""
        if wall_s <= 0:
            return None
        self.source.set_step(n_tokens, wall_s, t0=self.session.cursor)
        tag_groups: Dict[str, List[Request]] = {}
        for idx, req in slot_to_req.items():
            tag_groups.setdefault(self.slot_tag(idx), []).append(req)
        self.events.append({
            "phase": phase, "wall_s": wall_s, "n_tokens": n_tokens,
            "groups": {tg: [r.req_id for r in reqs]
                       for tg, reqs in tag_groups.items()}})
        try:
            block = self.session.sample(wall_s,
                                        tags=[phase] + sorted(tag_groups))
        finally:
            self.source.clear()
        per_tag = block.split_energy(
            {tg: len(reqs) for tg, reqs in tag_groups.items()})
        for tg, reqs in tag_groups.items():
            share = per_tag.get(tg, 0.0) / len(reqs)
            if share:
                for r in reqs:
                    r.energy_j += share
        return block

    def energy_stats(self) -> Dict:
        rep = self.session.report()
        out = {"energy_j": rep.energy_j, "energy_by_tag": dict(rep.by_tag)}
        if rep.counters:
            out["counters"] = dict(rep.counters)
        return out


# ---------------------------------------------------------------------------
# static-batch baseline


class ServeEngine:
    """Static batching: requests are padded into one fixed batch, prefilled
    together, and decoded in lock-step until the whole batch finishes. The
    baseline the continuous engine is benchmarked against."""

    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 telemetry: bool = True, dev: DeviceSpec = TPU_V5E,
                 prefill_buckets="auto"):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.buckets = resolve_buckets(prefill_buckets, max_seq, model)
        self.trace_stats = TraceStats()
        self.stats = ThroughputStats()
        self.pm = ServePowerModel(
            _count_params(params), dev=dev,
            cache_bytes=_cache_bytes(model, batch_size, max_seq))
        self.tel = EngineTelemetry(self.pm, batch_size) if telemetry else None
        self._prefill = counting_jit(
            make_prefill_step(model, bucketed=bool(self.buckets)),
            "prefill", self.trace_stats, on_compile=self._on_compile)
        self._decode = counting_jit(make_decode_step(model), "decode",
                                    self.trace_stats,
                                    on_compile=self._on_compile)

    def _on_compile(self, name: str):
        if self.tel is not None:
            self.tel.session.count(f"compiles/{name}")

    def _pad_prompts(self, reqs: List[Request]):
        """Left-pad prompts to the longest in the batch (position alignment:
        every row's last real token sits at ``s - 1``), then right-pad the
        whole batch to its bucket edge so prefill shapes stay bounded."""
        s = max(len(r.prompt) for r in reqs)
        sb = bucket_for(s, self.buckets) if self.buckets else s
        toks = np.zeros((self.batch_size, sb), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):s] = r.prompt   # left-pad
        return jnp.asarray(toks), s

    def serve(self, reqs: List[Request]) -> Dict:
        """One batch generation pass; returns stats."""
        assert reqs and len(reqs) <= self.batch_size
        pad = [Request(-1, np.zeros(1, np.int32), 0)
               for _ in range(self.batch_size - len(reqs))]
        tokens, s = self._pad_prompts(reqs + pad)
        caches = self.model.init_cache(self.batch_size, self.max_seq)
        win_cm = (self.tel.session.window() if self.tel
                  else contextlib.nullcontext())
        with win_cm as win:
            stats = self._serve_batch(reqs, tokens, s, caches)
        if self.tel:
            rep = win.report()      # this call's grid-aligned energy window
            stats["energy_j"] = rep.energy_j
            stats["energy_by_tag"] = dict(rep.by_tag)
        return stats

    def _serve_batch(self, reqs: List[Request], tokens, s: int,
                     caches) -> Dict:
        t0 = time.perf_counter()
        if self.buckets:
            logits, caches = self._prefill(self.params, {"tokens": tokens},
                                           jnp.int32(s), caches)
        else:
            logits, caches = self._prefill(self.params, {"tokens": tokens},
                                           caches)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cur_host = np.asarray(cur)
        t_prefill = time.perf_counter() - t0
        # attribute only the true prompt tokens: left-pad, bucket tail, and
        # filler rows are compute the batch burns, not request throughput
        n_prompt = sum(len(r.prompt) for r in reqs)
        self.stats.observe("prefill", n_prompt, t_prefill)
        if self.tel:
            self.tel.record("prefill", t_prefill, n_prompt,
                            {i: r for i, r in enumerate(reqs)})

        for r in reqs:
            if r.max_new_tokens <= 0:
                r.done = True
                r.finish_reason = "length"

        n_decoded = 0
        t_dec = 0.0
        step = 0
        while not all(r.done for r in reqs):
            # emit the token sampled from the last logits (prefill or decode)
            for bi, r in enumerate(reqs):
                if r.done:
                    continue
                tok = int(cur_host[bi, 0])
                r.output.append(tok)
                n_decoded += 1
                if r.eos_id is not None and tok == r.eos_id:
                    r.done = True
                    r.finish_reason = "eos"
                elif r.n_generated >= r.max_new_tokens:
                    r.done = True
                    r.finish_reason = "length"
            if all(r.done for r in reqs):
                break           # nothing left: the last logits are not wasted
            active = {bi: r for bi, r in enumerate(reqs) if not r.done}
            td0 = time.perf_counter()
            cur, _, caches = self._decode(self.params, cur,
                                          jnp.int32(s + step), caches)
            cur_host = np.asarray(cur)      # one host sync per step
            dt = time.perf_counter() - td0
            t_dec += dt
            step += 1
            # len(active), not batch_size: filler/finished rows decode as
            # dead weight and must not inflate throughput or touch energy
            # attribution (they own no slot tag)
            self.stats.observe("decode", len(active), dt)
            if self.tel:
                self.tel.record("decode", dt, len(active), active)

        return {
            "prefill_s": t_prefill,
            "decode_s": t_dec,
            "decode_steps": step,
            "tokens_decoded": n_decoded,
            "prompt_tokens": n_prompt,
            "decode_tok_per_s": n_decoded / t_dec if t_dec else 0.0,
            "prefill_compiles": self.trace_stats.compiles("prefill"),
            "decode_compiles": self.trace_stats.compiles("decode"),
        }


# ---------------------------------------------------------------------------
# continuous batching


class ContinuousEngine:
    """Continuous batching over one shared KV cache.

    Requests queue up (``submit``) and ``run`` drains them: free slots are
    filled via single-slot prefills (other slots keep their in-flight
    state), every decode step advances *all* active slots with one fused
    jitted call (per-slot positions, sampling inside jit, one [B,1] host
    fetch), and a slot is recycled the moment its request hits EOS or its
    token budget — so late requests join mid-decode instead of waiting for
    the batch to drain.
    """

    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 telemetry: bool = True, dev: DeviceSpec = TPU_V5E,
                 power_cap_w: Optional[float] = None, greedy: bool = True,
                 prefill_buckets="auto"):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.buckets = resolve_buckets(prefill_buckets, max_seq, model)
        self.trace_stats = TraceStats()
        self._decode = counting_jit(make_decode_step(model, greedy),
                                    "decode", self.trace_stats,
                                    on_compile=self._on_compile)
        self._prefill_slot = counting_jit(
            make_slot_prefill(model, bucketed=bool(self.buckets)),
            "prefill", self.trace_stats, on_compile=self._on_compile)
        self._reset_slot = jax.jit(reset_cache_slot)
        self.pm = ServePowerModel(
            _count_params(params), dev=dev,
            cache_bytes=_cache_bytes(model, batch_size, max_seq))
        self.stats = ThroughputStats()
        self.admission = AdmissionController(self.pm, power_cap_w, self.stats)
        self.queue = RequestQueue()
        self.slots = SlotManager(batch_size, max_seq)
        self.tel = EngineTelemetry(self.pm, batch_size) if telemetry else None
        self.caches = None
        self.dvfs = self.admission.apply_dvfs(batch_size)
        self.finished: List[Request] = []
        self._n_emitted = 0
        self._decode_s = 0.0
        self._prefill_s = 0.0
        self._decode_steps = 0

    def _on_compile(self, name: str):
        if self.tel is not None:
            self.tel.session.count(f"compiles/{name}")

    # -- request intake ------------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.req_id}: prompt({len(req.prompt)}) + "
                f"max_new({req.max_new_tokens}) exceeds max_seq={self.max_seq}")
        self.queue.push(req)

    # -- slot lifecycle ------------------------------------------------------

    def _finish(self, slot, reason: str):
        req = slot.req
        req.done = True
        req.finish_reason = reason
        self.finished.append(req)
        # recycle: zero the slot's cache rows so the next occupant starts clean
        self.caches = self._reset_slot(self.caches, jnp.int32(slot.index))
        self.slots.release(slot)

    def _emit(self, slot, tok: int):
        req = slot.req
        req.output.append(tok)
        self._n_emitted += 1
        if req.eos_id is not None and tok == req.eos_id:
            self._finish(slot, "eos")
        elif req.n_generated >= req.max_new_tokens:
            self._finish(slot, "length")

    def _shed_stale(self):
        """TTL shedding: a queued request's predicted wait is the remaining
        decode budget ahead of it (active slots + queue positions in front)
        cleared at the measured decode rate, plus the queued prompts ahead
        cleared at the measured prefill rate."""
        if not self.queue:
            return
        ahead = sum(s.req.max_new_tokens - s.req.n_generated
                    for s in self.slots.active_slots())
        ahead_prefill = 0
        for req in self.queue.snapshot():
            if self.admission.should_shed(req, ahead, ahead_prefill):
                self.queue.shed(req)     # shed() drops it from the queue too
            else:
                # a queued request costs its prompt (prefill) AND its
                # budget (decode) — tracked separately so each phase is
                # priced at its own measured rate
                ahead += req.max_new_tokens
                ahead_prefill += len(req.prompt)

    def _admit(self):
        """Fill free slots from the queue, subject to the admission policy."""
        self._shed_stale()
        while self.queue and self.slots.free_slots():
            if self.admission.max_slots(self.batch_size) == 0:
                while self.queue:        # cap below even 1-slot power: shed
                    self.queue.shed(self.queue.pop(), "shed-cap")
                break
            if not self.admission.admit(self.slots.n_active, self.batch_size):
                break                     # defer under the power cap
            req = self.queue.pop()
            if req.max_new_tokens <= 0:
                req.done = True
                req.finish_reason = "length"
                self.finished.append(req)
                continue
            self._prefill_into(self.slots.free_slots()[0], req)

    def _prefill_into(self, slot, req: Request):
        prompt = np.asarray(req.prompt, np.int32)
        t0 = time.perf_counter()
        if self.buckets:
            padded, n = pad_to_bucket(prompt, self.buckets)
            next_tok, _, self.caches = self._prefill_slot(
                self.params, jnp.asarray(padded[None, :]), jnp.int32(n),
                jnp.int32(slot.index), self.caches)
        else:
            next_tok, _, self.caches = self._prefill_slot(
                self.params, jnp.asarray(prompt[None, :]),
                jnp.int32(slot.index), self.caches)
        first = int(np.asarray(next_tok)[0, 0])
        dt = time.perf_counter() - t0
        req.prefill_s = dt
        self._prefill_s += dt
        self.stats.observe("prefill", len(req.prompt), dt)
        if self.tel:
            self.tel.record("prefill", dt, len(req.prompt), {slot.index: req})
        self.slots.assign(slot, req, first)
        self._emit(slot, first)   # prefill samples the first token

    def _decode_once(self):
        active = self.slots.active_slots()
        tokens = jnp.asarray(self.slots.batch_tokens())
        pos = jnp.asarray(self.slots.batch_positions())
        t0 = time.perf_counter()
        next_tok, _, self.caches = self._decode(self.params, tokens, pos,
                                                self.caches)
        toks = np.asarray(next_tok)          # one host sync per step
        dt = time.perf_counter() - t0
        self._decode_s += dt
        self._decode_steps += 1
        self.stats.observe("decode", len(active), dt)
        if self.tel:
            self.tel.record("decode", dt, len(active),
                            {s.index: s.req for s in active})
        for s in active:
            s.req.decode_steps += 1
            tok = int(toks[s.index, 0])
            self.slots.advance(s, tok)
            self._emit(s, tok)

    # -- driver --------------------------------------------------------------

    def run(self) -> Dict:
        """Drain the queue; returns aggregate + per-request stats."""
        if self.caches is None:
            self.caches = self.model.init_cache(self.batch_size, self.max_seq)
        while True:
            self._admit()
            if self.slots.n_active == 0:
                break
            self._decode_once()
        stats = {
            "completed": len(self.finished),
            "shed": self.queue.n_shed,
            "tokens_decoded": self._n_emitted,
            "prefill_s": self._prefill_s,
            "decode_s": self._decode_s,
            "decode_steps": self._decode_steps,
            "decode_tok_per_s": (self._n_emitted / self._decode_s
                                 if self._decode_s else 0.0),
            "prefills": self.slots.n_assigned,
            "prompt_tokens": self.slots.n_prefill_tokens,
            "slots_recycled": self.slots.n_released,
            "peak_active": self.slots.peak_active,
            "dvfs_f_ghz": self.dvfs.f_ghz if self.dvfs else None,
            "prefill_compiles": self.trace_stats.compiles("prefill"),
            "decode_compiles": self.trace_stats.compiles("decode"),
            "prefill_buckets": list(self.buckets) if self.buckets else None,
        }
        if self.tel:
            stats.update(self.tel.energy_stats())
        return stats

    def serve(self, reqs: List[Request]) -> Dict:
        """Convenience: submit all and drain."""
        for r in reqs:
            self.submit(r)
        return self.run()

    def reset_metrics(self):
        """Clear counters, queue state, and samples (benchmark warmup);
        jit caches and the KV buffer survive — freed slots are always
        re-prefilled before reuse, so stale KV is never read.
        ``trace_stats`` is intentionally NOT cleared: compile counts track
        the engine's lifetime executable set (the thing the bucket bound
        promises), while the telemetry session's ``compiles/*`` counters
        reset with the samples they annotate."""
        self.finished = []
        self._n_emitted = 0
        self._decode_s = 0.0
        self._prefill_s = 0.0
        self._decode_steps = 0
        self.queue = RequestQueue()
        self.slots = SlotManager(self.batch_size, self.max_seq)
        if self.tel:
            self.tel.session.reset()
            self.tel.events = []       # event log tracks the sample stream
