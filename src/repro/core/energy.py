"""Energy model: J/step and J/token from the compiled dry-run + DVFS.

The paper measures socket power at 1000 SPS; on the TPU target we *derive*
power from the compiled artifact instead: the roofline terms give per-chip
busy time and utilization, the DVFS model gives power at a frequency, and
the probe/mainboard pipeline replays the resulting trace so every
paper experiment (tagging, averaging, capping) runs identically.

DVFS model (standard cubic): P(f, u) = P_idle + (P_tdp - P_idle) * u * (f/f_max)^3
with throughput proportional to f for compute-bound work and ~flat for
memory-bound work (memory clock is not scaled).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.hw import DeviceSpec, TPU_V5E


@dataclasses.dataclass(frozen=True)
class DvfsState:
    f_ghz: float

    def rel(self, dev: DeviceSpec) -> float:
        return self.f_ghz / dev.f_max_ghz


STALL_UTIL = 0.35   # utilization while stalled on HBM/ICI (trace stall power)


def power_w(dev: DeviceSpec, util: float, dvfs: Optional[DvfsState] = None) -> float:
    """Instantaneous device power at utilization ``util`` in [0,1]."""
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    return dev.idle_w + (dev.tdp_w - dev.idle_w) * util * rel ** 3


def busy_fraction(roofline_terms, dvfs: Optional[DvfsState] = None,
                  dev: DeviceSpec = TPU_V5E,
                  t_step: Optional[float] = None) -> float:
    """Fraction of a step spent compute-busy (rest stalls at STALL_UTIL).

    The single source of the duty-cycle model shared by the trace
    generators and the admission-control power estimate."""
    t = t_step if t_step is not None else step_time_s(roofline_terms, dvfs, dev)
    if t <= 0:
        return 0.0
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    return min(roofline_terms["compute"] / max(rel, 1e-6) / t, 1.0)


def step_time_s(roofline_terms: Dict[str, float],
                dvfs: Optional[DvfsState] = None,
                dev: DeviceSpec = TPU_V5E,
                overlap: float = 1.0) -> float:
    """Predicted step time from the three roofline terms.

    overlap=1.0: perfect compute/comm overlap (max of terms);
    overlap=0.0: fully serialized (sum of terms).
    Compute scales 1/f; memory and collective terms do not.
    """
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    c = roofline_terms["compute"] / max(rel, 1e-6)
    m = roofline_terms["memory"]
    x = roofline_terms["collective"]
    t_overlap = max(c, m, x)
    t_serial = c + m + x
    return overlap * t_overlap + (1.0 - overlap) * t_serial


def step_energy_j(roofline_terms: Dict[str, float],
                  dvfs: Optional[DvfsState] = None,
                  dev: DeviceSpec = TPU_V5E,
                  overlap: float = 1.0) -> float:
    """Per-chip energy of one step: P(util, f) * t_step."""
    t = step_time_s(roofline_terms, dvfs, dev, overlap)
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    busy = roofline_terms["compute"] / max(rel, 1e-6)
    util = min(busy / t, 1.0) if t > 0 else 0.0
    return power_w(dev, util, dvfs) * t


def tokens_per_joule(roofline_terms, tokens_per_step, n_chips,
                     dvfs=None, dev=TPU_V5E) -> float:
    e = step_energy_j(roofline_terms, dvfs, dev) * n_chips
    return tokens_per_step / e if e else 0.0


def power_trace_fn(roofline_terms, dvfs=None, dev: DeviceSpec = TPU_V5E,
                   period_s: Optional[float] = None) -> Callable[[float], float]:
    """power(t) for one chip running repeated steps — drives the probes.

    Within each step the trace is piecewise: compute-bound phase at high
    power, then memory/collective-bound phase at lower power (utilization
    drops while waiting on HBM/ICI).
    """
    t_step = period_s or step_time_s(roofline_terms, dvfs, dev)
    t_busy = busy_fraction(roofline_terms, dvfs, dev, t_step) * t_step

    def fn(t):
        # np.where keeps the trace array-capable: the columnar probe path
        # evaluates whole timestamp windows in one call
        util = np.where(t % t_step < t_busy, 1.0, STALL_UTIL)
        return power_w(dev, util, dvfs)

    return fn


# ---------------------------------------------------------------------------
# serving-phase power model (drives the ServeEngine probes)


def serve_roofline_terms(n_params_active: float, n_tokens: int,
                         dev: DeviceSpec = TPU_V5E,
                         param_bytes: Optional[float] = None,
                         cache_bytes: float = 0.0) -> Dict[str, float]:
    """Roofline terms for one serving step processing ``n_tokens``.

    compute: 2·N·tokens matmul FLOPs; memory: one weight (+ cache) reload —
    the decode regime where batch=n_active keeps compute tiny against the
    fixed weight-streaming cost, so power is utilization- and phase-
    dependent rather than a constant.
    """
    pb = param_bytes if param_bytes is not None else 2.0 * n_params_active
    compute = 2.0 * n_params_active * max(n_tokens, 1) / dev.peak_flops
    memory = (pb + cache_bytes) / dev.mem_bw
    return {"compute": compute, "memory": memory, "collective": 0.0}


def scaled_power_trace_fn(roofline_terms, wall_s: float,
                          dvfs: Optional[DvfsState] = None,
                          dev: DeviceSpec = TPU_V5E) -> Callable[[float], float]:
    """power(t) over a *measured* wall-clock window.

    The engine may run on any host backend (CPU smoke runs are orders of
    magnitude slower than the modeled deployment chip), so the modeled
    step's busy/stall duty cycle is stretched onto the observed duration:
    average power over the window equals the model's average step power.
    """
    busy_frac = busy_fraction(roofline_terms, dvfs, dev)

    def fn(t):
        phase = (t % wall_s) / wall_s if wall_s > 0 else np.ones_like(t)
        util = np.where(phase < busy_frac, 1.0, STALL_UTIL)
        return power_w(dev, util, dvfs)

    return fn


class ServePowerModel:
    """Phase-aware node power for the serving engine.

    Replaces hardcoded watt constants with traces derived from the
    roofline/DVFS energy model: prefill of S tokens is compute-heavy,
    decode with n active slots is weight-streaming-bound, and an idle
    engine draws ``dev.idle_w``. A DVFS state (e.g. from ``cap_frequency``)
    scales every derived trace.
    """

    def __init__(self, n_params_active: float, dev: DeviceSpec = TPU_V5E,
                 param_bytes: Optional[float] = None,
                 dvfs: Optional[DvfsState] = None,
                 cache_bytes: float = 0.0):
        self.n_params = float(n_params_active)
        self.dev = dev
        self.param_bytes = (param_bytes if param_bytes is not None
                            else 2.0 * self.n_params)
        self.dvfs = dvfs
        self.cache_bytes = cache_bytes   # live KV footprint (engine-set)

    def terms(self, n_tokens: int) -> Dict[str, float]:
        return serve_roofline_terms(self.n_params, n_tokens, self.dev,
                                    self.param_bytes, self.cache_bytes)

    def trace(self, n_tokens: int, wall_s: float) -> Callable[[float], float]:
        """power(t) for a step processing ``n_tokens``, stretched to the
        measured ``wall_s`` window (local t starting at 0)."""
        return scaled_power_trace_fn(self.terms(n_tokens), wall_s,
                                     self.dvfs, self.dev)

    def avg_power_w(self, n_tokens: int) -> float:
        """Average power of the derived trace at the current DVFS state
        (duty-cycle-weighted, so it matches what the probes will report)."""
        busy_frac = busy_fraction(self.terms(n_tokens), self.dvfs, self.dev)
        return (busy_frac * power_w(self.dev, 1.0, self.dvfs)
                + (1.0 - busy_frac) * power_w(self.dev, STALL_UTIL, self.dvfs))

    def idle_power_w(self) -> float:
        return self.dev.idle_w


# ---------------------------------------------------------------------------
# power capping (paper Sec. 3.6: RAPL / nvidia-smi power caps)


def cap_frequency(cap_w: float, roofline_terms, dev: DeviceSpec = TPU_V5E,
                  n_steps: int = 32) -> DvfsState:
    """Highest frequency whose average step power is within the cap.

    Discrete frequency ladder (like cpufreq governors); returns f_min even
    if the cap is unreachable (can't go below idle).
    """
    for i in range(n_steps, -1, -1):
        f = dev.f_min_ghz + (dev.f_max_ghz - dev.f_min_ghz) * i / n_steps
        st = DvfsState(f)
        t = step_time_s(roofline_terms, st, dev)
        e = step_energy_j(roofline_terms, st, dev)
        if t > 0 and e / t <= cap_w:
            return st
    return DvfsState(dev.f_min_ghz)


def pareto_frontier(roofline_terms, dev: DeviceSpec = TPU_V5E, n: int = 16):
    """(f, time, energy) sweep — the energy/performance trade-off the paper's
    DVFS + measurement platform is built to explore."""
    out = []
    for i in range(n + 1):
        f = dev.f_min_ghz + (dev.f_max_ghz - dev.f_min_ghz) * i / n
        st = DvfsState(f)
        out.append({
            "f_ghz": f,
            "step_s": step_time_s(roofline_terms, st, dev),
            "step_j": step_energy_j(roofline_terms, st, dev),
        })
    return out
