"""internvl2-76b — VLM: InternViT frontend (STUB) + InternLM2-like 80L backbone
[arXiv:2404.16821; unverified]. Backbone only; patch embeddings precomputed.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    frontend_stub=True, stub_prefix_len=256,
    source="arXiv:2404.16821",
)

SMOKE_CONFIG = CONFIG.replace(
    name="internvl2-76b-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=16, stub_prefix_len=8,
)
