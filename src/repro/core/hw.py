"""Hardware registry: DALEK's partitions (paper Tab. 1/2) + TPU v5e pods.

The paper's core idea — *manage heterogeneous compute with first-class energy
accounting* — needs a device model: peak compute, memory bandwidth, link
bandwidth, TDP, idle and suspend power. The registry carries the paper's four
consumer-grade partitions verbatim (used by the fidelity tests that reproduce
Tab. 2 totals) and the TPU v5e target the framework deploys on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One compute device (CPU, GPU, or TPU chip)."""

    name: str
    vendor: str
    kind: str                  # cpu | gpu | tpu | npu
    peak_flops: float          # FLOP/s at the headline dtype
    peak_dtype: str
    mem_bw: float              # B/s
    mem_gb: float
    tdp_w: float
    idle_w: float = 0.0
    # DVFS envelope
    f_max_ghz: float = 1.0
    f_min_ghz: float = 0.5


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    name: str
    devices: Tuple[DeviceSpec, ...]
    ram_gb: float
    idle_w: float
    suspend_w: float
    tdp_w: float
    boot_s: float = 120.0      # paper: up to 2 min between alloc and job start
    net_gbps: float = 2.5      # paper: 2.5 GbE


@dataclasses.dataclass(frozen=True)
class PartitionSpec_:
    """A homogeneous group of nodes (paper: four nodes per partition)."""

    name: str
    node: NodeSpec
    n_nodes: int

    @property
    def idle_w(self):
        return self.node.idle_w * self.n_nodes

    @property
    def suspend_w(self):
        return self.node.suspend_w * self.n_nodes

    @property
    def tdp_w(self):
        return self.node.tdp_w * self.n_nodes


# --------------------------------------------------------------------------
# DALEK's devices (paper Tab. 1/2)

RYZEN_7945HX = DeviceSpec("Ryzen 9 7945HX", "amd", "cpu", 1.6e12, "f32",
                          83e9, 96, 75, 15, 5.4, 3.0)
CORE_ULTRA_185H = DeviceSpec("Core Ultra 9 185H", "intel", "cpu", 0.9e12, "f32",
                             90e9, 32, 115, 12, 5.1, 0.7)
RYZEN_AI_HX370 = DeviceSpec("Ryzen AI 9 HX 370", "amd", "cpu", 0.8e12, "f32",
                            120e9, 32, 54, 8, 5.1, 1.0)
CORE_I9_13900H = DeviceSpec("Core i9-13900H", "intel", "cpu", 0.7e12, "f32",
                            80e9, 96, 115, 10, 5.4, 0.8)
RTX_4090 = DeviceSpec("GeForce RTX 4090", "nvidia", "gpu", 82.6e12, "f32",
                      1008e9, 24, 450, 20, 2.52, 0.21)
RX_7900XTX = DeviceSpec("Radeon RX 7900 XTX", "amd", "gpu", 61.4e12, "f32",
                        960e9, 24, 300, 15, 2.5, 0.5)
ARC_A770 = DeviceSpec("Arc A770", "intel", "gpu", 39.3e12, "f32",
                      560e9, 16, 225, 35, 2.4, 0.3)
RADEON_890M = DeviceSpec("Radeon 890M", "amd", "gpu", 12.0e12, "f16",
                         96e9, 0, 30, 3, 2.9, 0.4)

# --------------------------------------------------------------------------
# TPU v5e (deployment target; assignment constants)

TPU_V5E = DeviceSpec("TPU v5e", "google", "tpu", 197e12, "bf16",
                     819e9, 16, 220, 60, 1.0, 0.5)
TPU_V5E_ICI_BW = 50e9      # B/s per link
TPU_V5E_DCN_BW = 25e9      # B/s inter-pod share per chip


def _dalek_node(name, cpu, gpu, ram, idle, susp, tdp, net=2.5):
    devs = (cpu,) + ((gpu,) if gpu else ())
    return NodeSpec(name, devs, ram, idle, susp, tdp, net_gbps=net)


# paper Tab. 2 rows (per-node power derived from 4-node partition totals)
DALEK_PARTITIONS: Dict[str, PartitionSpec_] = {
    "az4-n4090": PartitionSpec_(
        "az4-n4090", _dalek_node("az4-n4090", RYZEN_7945HX, RTX_4090,
                                 96, 53.0, 1.5, 525.0), 4),
    "az4-a7900": PartitionSpec_(
        "az4-a7900", _dalek_node("az4-a7900", RYZEN_7945HX, RX_7900XTX,
                                 96, 48.0, 1.5, 375.0), 4),
    "iml-ia770": PartitionSpec_(
        "iml-ia770", _dalek_node("iml-ia770", CORE_ULTRA_185H, ARC_A770,
                                 32, 65.0, 23.0, 340.0, net=5.0), 4),
    "az5-a890m": PartitionSpec_(
        "az5-a890m", _dalek_node("az5-a890m", RYZEN_AI_HX370, RADEON_890M,
                                 32, 4.0, 2.0, 54.0), 4),
}

FRONTEND = NodeSpec("front", (CORE_I9_13900H,), 96, 15.0, 15.0, 115.0,
                    net_gbps=20.0)
SWITCH_IDLE_W, SWITCH_TDP_W = 20.0, 100.0
RPI_IDLE_W, RPI_TDP_W, N_RPI = 3.0, 9.0, 4

# paper Tab. 2 "Total" row for fidelity checks
PAPER_TOTALS = {"idle_w": 727.0, "suspend_w": 112.0, "tdp_w": 5427.0}


def tpu_pod_partition(name="v5e-pod", n_chips=256, chips_per_node=4):
    node = NodeSpec(
        f"{name}-host", (TPU_V5E,) * chips_per_node,
        ram_gb=128, idle_w=chips_per_node * TPU_V5E.idle_w + 150,
        suspend_w=12.0, tdp_w=chips_per_node * TPU_V5E.tdp_w + 350,
        boot_s=300.0, net_gbps=100.0)
    return PartitionSpec_(name, node, n_chips // chips_per_node)


def cluster_idle_w(mode: str = "off") -> float:
    """Cluster power with all compute nodes in a given state.

    mode="off": paper Sec. 3.4 — nodes powered down after 10 min idle, only
    frontend + switch + RPis draw power (~50 W).
    mode="suspend": S3 (paper Tab. 2 suspend column).
    mode="idle": all nodes booted but idle (Tab. 2 idle column).
    """
    base = FRONTEND.idle_w + SWITCH_IDLE_W + N_RPI * RPI_IDLE_W
    if mode == "off":
        return base
    if mode == "suspend":
        return base + sum(p.suspend_w for p in DALEK_PARTITIONS.values())
    return base + sum(p.idle_w for p in DALEK_PARTITIONS.values())
