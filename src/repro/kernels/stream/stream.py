"""STREAM-suite Pallas kernels (paper Fig. 4 / Sec. 5.1 `bandwidth`).

The paper's bandwidth benchmark measures read/write/copy/scale/add/triad
across the memory hierarchy. On TPU the hierarchy is HBM -> VMEM -> VREG;
these kernels stream HBM-resident buffers through VMEM tiles (BlockSpec)
exactly like the paper's explicitly vectorized loops stream through cache
lines (non-temporal stores map to the one-pass VMEM write-back).

Grid: 1-D over row blocks; each program handles a (block_rows, cols) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(a_ref, o_ref):
    o_ref[...] = a_ref[...]


def _scale_kernel(x_scalar_ref, a_ref, o_ref):
    o_ref[...] = a_ref[...] * x_scalar_ref[0]


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(x_scalar_ref, a_ref, b_ref, o_ref):
    o_ref[...] = x_scalar_ref[0] * a_ref[...] + b_ref[...]


def _write_kernel(x_scalar_ref, o_ref):
    o_ref[...] = jnp.full_like(o_ref, x_scalar_ref[0])


def _read_kernel(a_ref, o_ref):
    # reduce to one scalar per tile: reads the stream, writes O(1)
    o_ref[0, 0] = jnp.sum(a_ref[...])


def _blocks(shape, block_rows):
    rows, cols = shape
    block_rows = min(block_rows, rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    return grid, spec


def stream_copy(a, *, block_rows=256, interpret=False):
    grid, spec = _blocks(a.shape, block_rows)
    return pl.pallas_call(
        _copy_kernel, grid=grid, in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret)(a)


def stream_scale(a, x, *, block_rows=256, interpret=False):
    grid, spec = _blocks(a.shape, block_rows)
    xs = jnp.asarray([x], a.dtype)
    return pl.pallas_call(
        _scale_kernel, grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret)(xs, a)


def stream_add(a, b, *, block_rows=256, interpret=False):
    grid, spec = _blocks(a.shape, block_rows)
    return pl.pallas_call(
        _add_kernel, grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret)(a, b)


def stream_triad(a, b, x, *, block_rows=256, interpret=False):
    grid, spec = _blocks(a.shape, block_rows)
    xs = jnp.asarray([x], a.dtype)
    return pl.pallas_call(
        _triad_kernel, grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret)(xs, a, b)


def stream_write(shape, x, dtype=jnp.float32, *, block_rows=256,
                 interpret=False):
    grid, spec = _blocks(shape, block_rows)
    xs = jnp.asarray([x], dtype)
    return pl.pallas_call(
        _write_kernel, grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        interpret=interpret)(xs)


def stream_read(a, *, block_rows=256, interpret=False):
    rows, cols = a.shape
    block_rows = min(block_rows, rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _read_kernel, grid=grid,
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), a.dtype),
        interpret=interpret)(a)
