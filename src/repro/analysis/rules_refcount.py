"""DLK006 refcount-pairing.

``PagePool.alloc``/``retain`` bump a block's refcount; a handle that is
dropped (or abandoned on an early exit) leaks the block until the pool
is torn down — under memory pressure that shows up as spurious
admission-control rejections, not a crash, so it survives testing. The
rule is lexical: an alloc result must be *consumed* (stored, passed,
returned, or freed), and no plain return/raise may sit between the
alloc and its first consumption — except under the ``if blk is None``
failure guard, where there is nothing to release.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import (Finding, ModuleContext, Rule, qualname,
                                 register)

_POOLISH = ("pool", "page")


def _pool_receiver(func) -> Optional[str]:
    """Receiver text if this is ``<pool>.alloc``/``<pool>.retain`` on
    something pool-shaped. ``self.alloc`` (the pool's own implementation)
    is exempt — pairing inside the pool is the pool's invariant, checked
    by its tests, not by call-site lint."""
    if not isinstance(func, ast.Attribute):
        return None
    recv = qualname(func.value)
    if not recv or recv == "self":
        return None
    probe = recv[5:] if recv.startswith("self.") else recv
    if any(p in probe.lower() for p in _POOLISH):
        return recv
    return None


def _is_none_guard(test, name: str) -> bool:
    """``blk is None`` anywhere in the test (possibly or-joined)."""
    for sub in ast.walk(test):
        if isinstance(sub, ast.Compare) \
                and isinstance(sub.left, ast.Name) and sub.left.id == name \
                and any(isinstance(op, ast.Is) for op in sub.ops) \
                and any(isinstance(c, ast.Constant) and c.value is None
                        for c in sub.comparators):
            return True
    return False


@register
class RefcountPairing(Rule):
    """Pool blocks acquired but not consumed/released on every path."""

    code = "DLK006"
    name = "refcount-pairing"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("alloc", "retain")):
                continue
            recv = _pool_receiver(node.func)
            if recv is None:
                continue
            parent = ctx.parent(node)

            # alloc whose result is dropped: refcount went up, handle gone
            if node.func.attr == "alloc" and isinstance(parent, ast.Expr):
                yield ctx.finding(
                    self, node,
                    f"result of {recv}.alloc() discarded — the block's "
                    "refcount was bumped but the handle is lost (leak)")
                continue
            if node.func.attr != "alloc":
                continue    # bare retain(expr) pairs with a stored handle
            if not isinstance(parent, ast.Assign):
                continue    # alloc feeding a call/return is consumed inline
            tgt = parent.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            name = tgt.id
            fn = ctx.enclosing_function(node)
            scope = fn if fn is not None else ctx.tree

            # first later *use* of the handle (free()/store/pass/return all
            # count — any of them either releases or transfers ownership)
            uses = sorted(n.lineno for n in ast.walk(scope)
                          if isinstance(n, ast.Name) and n.id == name
                          and isinstance(n.ctx, ast.Load)
                          and n.lineno > parent.lineno)
            if not uses:
                yield ctx.finding(
                    self, node,
                    f"'{name}' = {recv}.alloc() is never used afterwards — "
                    "acquired block is neither stored nor released")
                continue
            first_use = uses[0]
            for exit_ in ast.walk(scope):
                if not isinstance(exit_, (ast.Return, ast.Raise)):
                    continue
                if not parent.lineno < exit_.lineno < first_use:
                    continue
                guarded = any(
                    isinstance(anc, ast.If) and _is_none_guard(anc.test, name)
                    for anc in ctx.ancestors(exit_))
                if guarded:
                    continue    # alloc failed; nothing to release
                kind = "return" if isinstance(exit_, ast.Return) else "raise"
                yield ctx.finding(
                    self, exit_,
                    f"{kind} between '{name} = {recv}.alloc()' (line "
                    f"{parent.lineno}) and its first use — the block "
                    "leaks on this path")
