"""Reading and writing ``.dkt`` trace files.

``TraceWriter`` is append-only: declare streams, append one chunk per
``SampleBlock``, close to seal the footer (index + tag table + user meta).
``TraceReader`` memory-maps the file, parses the footer, and serves
O(log chunks) time seeks plus zero-copy columnar reads — a multi-gigabyte
recording can be scanned chunk by chunk without materializing it.

    with TraceWriter(path, meta={"run": "smoke"}) as w:
        sid = w.add_stream("az5-a890m-0/chip0", node="az5-a890m-0", sps=1000)
        w.append(sid, block)

    with TraceReader(path) as r:
        block = r.read(sid)                      # whole stream, one block
        tail = r.read(sid, t0=1.0)               # seek: chunks past t=1 s
        for b in r.blocks(sid):                  # streaming, chunk by chunk
            ...
"""
from __future__ import annotations

import mmap
import os
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.telemetry.samples import SampleBlock
from repro.tracestore import format as fmt


class TraceWriter:
    """Append-only ``.dkt`` writer; one chunk per appended block."""

    def __init__(self, path, meta: Optional[Dict] = None):
        self.path = os.fspath(path)
        self.meta: Dict = dict(meta or {})
        self._f = open(self.path, "wb")
        self._f.write(fmt.encode_header())
        self._offset = fmt.HEADER.size
        self._streams: List[Dict] = []
        self._tags: List[str] = []
        self._tag_ids: Dict[str, int] = {}
        self._chunks: List[fmt.ChunkInfo] = []
        self._closed = False

    def _intern_tag(self, name: str) -> int:
        tid = self._tag_ids.get(name)
        if tid is None:
            tid = self._tag_ids[name] = len(self._tags)
            self._tags.append(name)
        return tid

    def add_stream(self, name: str, **attrs) -> int:
        """Declare a stream (one probe's sample timeline); returns its id.
        ``attrs`` (node, device, sps, volts, ...) land in the footer."""
        sid = len(self._streams)
        self._streams.append({"id": sid, "name": name, **attrs})
        return sid

    @property
    def n_streams(self) -> int:
        return len(self._streams)

    @property
    def n_chunks(self) -> int:
        return len(self._chunks)

    def append(self, stream_id: int, block: SampleBlock) -> fmt.ChunkInfo:
        """Append one block as a chunk (empty blocks round-trip too: a
        window that produced no reports is still a window on replay)."""
        if self._closed:
            raise RuntimeError("TraceWriter is closed")
        if not 0 <= stream_id < len(self._streams):
            raise ValueError(f"unknown stream id {stream_id}")
        payload = fmt.encode_chunk(stream_id, block, self._intern_tag)
        info = fmt.chunk_info(stream_id, self._offset, len(payload), block)
        self._f.write(payload)
        self._offset += len(payload)
        self._chunks.append(info)
        return info

    def close(self) -> str:
        """Seal the file (footer + trailer); idempotent."""
        if not self._closed:
            self._f.write(fmt.encode_footer(self._streams, self._tags,
                                            self._chunks, self.meta))
            self._f.close()
            self._closed = True
        return self.path

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc):
        self.close()


class TraceReader:
    """mmap-backed ``.dkt`` reader with per-stream chunk indexes."""

    def __init__(self, path, use_mmap: bool = True):
        self.path = os.fspath(path)
        self._f = open(self.path, "rb")
        if use_mmap and os.fstat(self._f.fileno()).st_size > 0:
            self._buf = mmap.mmap(self._f.fileno(), 0, access=mmap.ACCESS_READ)
        else:
            self._buf = self._f.read()
        self.version = fmt.decode_header(self._buf[:fmt.HEADER.size])
        doc = fmt.decode_footer(self._buf)
        self.streams: List[Dict] = doc["streams"]
        self.tags: List[str] = doc["tags"]
        self.meta: Dict = doc.get("meta", {})
        self._chunks: Dict[int, List[fmt.ChunkInfo]] = {
            s["id"]: [] for s in self.streams}
        for row in doc["chunks"]:
            info = fmt.ChunkInfo.from_row(row)
            self._chunks.setdefault(info.stream_id, []).append(info)
        # per-stream end-timestamp key for O(log chunks) time seeks; the
        # running maximum keeps the key sorted even though empty chunks
        # record t0=t1=0.0 (an empty window between non-empty ones must not
        # break the binary search)
        self._t1s: Dict[int, np.ndarray] = {
            sid: (np.maximum.accumulate(np.array([c.t1 for c in chunks]))
                  if chunks else np.zeros(0))
            for sid, chunks in self._chunks.items()}

    # -- inventory -----------------------------------------------------------

    def stream_ids(self) -> List[int]:
        return [s["id"] for s in self.streams]

    def stream(self, stream_id: int) -> Dict:
        for s in self.streams:
            if s["id"] == stream_id:
                return s
        raise KeyError(f"no stream {stream_id} in {self.path}")

    def chunks(self, stream_id: int) -> List[fmt.ChunkInfo]:
        return list(self._chunks.get(stream_id, []))

    def n_samples(self, stream_id: Optional[int] = None) -> int:
        if stream_id is not None:
            return sum(c.n for c in self._chunks.get(stream_id, []))
        return sum(c.n for cs in self._chunks.values() for c in cs)

    def time_range(self, stream_id: int) -> tuple:
        """(t_first, t_last) over the stream's non-empty chunks."""
        ne = [c for c in self._chunks.get(stream_id, []) if c.n]
        if not ne:
            return (0.0, 0.0)
        return (ne[0].t0, ne[-1].t1)

    # -- reads ---------------------------------------------------------------

    def read_chunk(self, info: fmt.ChunkInfo) -> SampleBlock:
        sid, block, end = fmt.decode_chunk(self._buf, info.offset, self.tags)
        if sid != info.stream_id or end != info.offset + info.nbytes:
            raise fmt.TraceFormatError(
                f"chunk at {info.offset} disagrees with the footer index")
        return block

    def blocks(self, stream_id: int) -> Iterator[SampleBlock]:
        """Stream a stream's chunks in append order (window boundaries
        preserved — replay re-drives sessions window by window)."""
        for info in self._chunks.get(stream_id, []):
            yield self.read_chunk(info)

    def seek(self, stream_id: int, t: float) -> int:
        """Index of the first chunk whose span ends at or after ``t``
        (``len(chunks)`` when the whole stream is earlier). Footer-index
        binary search only; no payload bytes are touched."""
        return int(np.searchsorted(self._t1s.get(stream_id, np.zeros(0)), t,
                                   side="left"))

    def read(self, stream_id: int, t0: Optional[float] = None,
             t1: Optional[float] = None) -> SampleBlock:
        """One concatenated block for ``[t0, t1]`` (whole stream when
        unbounded), trimmed to the samples inside the span."""
        chunks = self._chunks.get(stream_id, [])
        lo = self.seek(stream_id, t0) if t0 is not None else 0
        picked = []
        for info in chunks[lo:]:
            if t1 is not None and info.n and info.t0 > t1:
                break
            picked.append(self.read_chunk(info))
        block = SampleBlock.concat(picked)
        if block.n and (t0 is not None or t1 is not None):
            lo_i = int(np.searchsorted(block.t, t0, "left")) if t0 is not None else 0
            hi_i = int(np.searchsorted(block.t, t1, "right")) if t1 is not None else block.n
            block = slice_block(block, lo_i, hi_i)
        return block

    def close(self):
        if isinstance(self._buf, mmap.mmap):
            try:
                self._buf.close()
            except BufferError:
                pass    # decoded blocks still view the map; the mapping is
                        # released when the last view is collected
        self._f.close()

    def __enter__(self) -> "TraceReader":
        return self

    def __exit__(self, *exc):
        self.close()


def slice_block(block: SampleBlock, lo: int, hi: int) -> SampleBlock:
    """Sample-range slice preserving the segment structure."""
    lo = max(0, min(lo, block.n))
    hi = max(lo, min(hi, block.n))
    if lo == 0 and hi == block.n:
        return block
    bounds, maps = [0], []
    for k, m in enumerate(block.seg_maps):
        s = max(int(block.seg_bounds[k]), lo)
        e = min(int(block.seg_bounds[k + 1]), hi)
        if e > s:
            bounds.append(e - lo)
            maps.append(m)
    if len(bounds) == 1:
        bounds = [0] if hi == lo else [0, hi - lo]
        maps = [{}] if hi > lo else []
    return SampleBlock(t=block.t[lo:hi], volts=block.volts[lo:hi],
                       watts=block.watts[lo:hi], dt=block.dt[lo:hi],
                       bits=block.bits[lo:hi],
                       seg_bounds=np.asarray(bounds, np.int64),
                       seg_maps=tuple(maps), n_avg=block.n_avg)
