"""Deterministic, sharded, prefetching data pipeline.

Synthetic token streams (seeded, reproducible across restarts by step index —
required for checkpoint-restart determinism) plus a file-backed variant.
Batches are produced per-host and placed onto the mesh with the batch
sharding; a background thread prefetches ``prefetch`` batches ahead.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    # synthetic structure: repeated n-grams so the model has learnable signal
    ngram: int = 8


class SyntheticTokens:
    """Step-indexed batches: batch(i) is a pure function of (seed, i)."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # n-gram markov-ish stream: learnable structure, not pure noise
        base = rng.integers(0, v, (b, s // cfg.ngram + 2, 1))
        grams = (base + np.arange(cfg.ngram)[None, None, :]) % v
        tokens = grams.reshape(b, -1)[:, :s].astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1  # ignore last position
        out = {"tokens": tokens, "labels": labels}
        mc = self.model_cfg
        if mc is not None and mc.family == "audio":
            out["frames"] = rng.standard_normal(
                (b, mc.enc_seq, mc.d_model)).astype(np.float32)
        if mc is not None and mc.family == "vlm":
            out["patch_embeddings"] = rng.standard_normal(
                (b, mc.stub_prefix_len, mc.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


class Prefetcher:
    """Background-thread prefetch + device placement."""

    def __init__(self, source, start_step: int = 0, prefetch: int = 2,
                 shardings=None):
        self.source = source
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        i = self._step
        while not self._stop.is_set():
            batch = self.source.batch(i)
            if self.shardings is not None:
                batch = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), batch, self.shardings)
            try:
                self._q.put((i, batch), timeout=1.0)
                i += 1
            except queue.Full:
                continue

    def next(self):
        i, batch = self._q.get()
        return i, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def for_cell(model_cfg: ModelConfig, shape: ShapeConfig, seed=0) -> SyntheticTokens:
    return SyntheticTokens(
        DataConfig(seed=seed, vocab_size=model_cfg.vocab_size,
                   seq_len=shape.seq_len, global_batch=shape.global_batch),
        model_cfg)
