"""Elastic node power management (paper Sec. 3.4).

SLURM hooks on DALEK: noderesume sends a Wake-on-LAN magic packet, a
dedicated ``powerstate`` user shuts nodes down via passwordless sudo over
SSH. Policy: power off after 10 minutes idle; up to 2 minutes boot delay
between reservation and job start; idle cluster draws ~50 W.

This module is the framework's elasticity engine: the same state machine
drives the simulated DALEK partitions and (on a real deployment) the TPU
pod autoscaler. Training integrates via the cluster manager: jobs trigger
resume, idle timers trigger suspend, and energy accounting integrates power
over state dwell times.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional

from repro.core.hw import NodeSpec

IDLE_OFF_S = 600.0        # paper: 10 minutes
DEFAULT_BOOT_S = 120.0    # paper: up to 2 minutes


class PowerState(enum.Enum):
    OFF = "off"
    BOOTING = "booting"
    IDLE = "idle"
    BUSY = "busy"
    SUSPENDED = "suspended"


@dataclasses.dataclass
class NodePower:
    spec: NodeSpec
    state: PowerState = PowerState.OFF
    since: float = 0.0               # state entry time
    boot_done: float = 0.0
    energy_j: float = 0.0
    transitions: int = 0

    def watts(self) -> float:
        if self.state == PowerState.OFF:
            return 0.0
        if self.state == PowerState.SUSPENDED:
            return self.spec.suspend_w
        if self.state == PowerState.BOOTING:
            return self.spec.idle_w          # boot draws ~idle
        if self.state == PowerState.IDLE:
            return self.spec.idle_w
        return self.spec.tdp_w


class ElasticController:
    """Event-driven power state machine over a set of nodes."""

    def __init__(self, nodes: Dict[str, NodeSpec],
                 idle_off_s: float = IDLE_OFF_S):
        self.nodes: Dict[str, NodePower] = {
            name: NodePower(spec) for name, spec in nodes.items()}
        self.idle_off_s = idle_off_s
        self.t = 0.0
        self.log: List[tuple] = []

    def _set(self, name: str, state: PowerState):
        np_ = self.nodes[name]
        if np_.state != state:
            np_.transitions += 1
            self.log.append((self.t, name, np_.state.value, state.value))
        np_.state = state
        np_.since = self.t

    def advance(self, dt: float):
        """Integrate energy, apply idle-timeout power-off, finish boots."""
        end = self.t + dt
        for name, np_ in self.nodes.items():
            t = self.t
            # boot completion inside the window
            if np_.state == PowerState.BOOTING and np_.boot_done <= end:
                np_.energy_j += np_.watts() * (np_.boot_done - t)
                t_save, self.t = self.t, np_.boot_done
                self._set(name, PowerState.IDLE)
                self.t = t_save
                t = np_.boot_done
            # idle timeout inside the window
            if np_.state == PowerState.IDLE:
                off_at = np_.since + self.idle_off_s
                if off_at <= end:
                    np_.energy_j += np_.watts() * max(off_at - t, 0.0)
                    t_save, self.t = self.t, off_at
                    self._set(name, PowerState.OFF)
                    self.t = t_save
                    t = off_at
            np_.energy_j += np_.watts() * max(end - t, 0.0)
        self.t = end

    # -- SLURM hook analogues -------------------------------------------------

    def resume(self, names: List[str]) -> float:
        """noderesume (WoL): returns the time when all nodes are up."""
        ready = self.t
        for n in names:
            np_ = self.nodes[n]
            if np_.state in (PowerState.OFF, PowerState.SUSPENDED):
                self._set(n, PowerState.BOOTING)
                np_.boot_done = self.t + np_.spec.boot_s
                ready = max(ready, np_.boot_done)
            elif np_.state == PowerState.BOOTING:
                ready = max(ready, np_.boot_done)
        return ready

    def mark_busy(self, names: List[str]):
        for n in names:
            if self.nodes[n].state != PowerState.BUSY:
                self._set(n, PowerState.BUSY)

    def release(self, names: List[str]):
        """nodesuspend path: back to IDLE; idle timer starts now."""
        for n in names:
            self._set(n, PowerState.IDLE)

    # -- accounting -----------------------------------------------------------

    def total_power_w(self) -> float:
        return sum(n.watts() for n in self.nodes.values())

    def total_energy_j(self) -> float:
        return sum(n.energy_j for n in self.nodes.values())

    def states(self) -> Dict[str, str]:
        return {n: p.state.value for n, p in self.nodes.items()}
