"""Cross-run bench regression gate.

Diffs the current run's ``--json`` bench rows against the previous run's
uploaded artifact and fails (exit 1) on:

- a relative slowdown beyond ``--threshold`` (default 15%) on any row's
  ``us_per_call``, or
- ANY increase in a row's ``compiles`` field — compile counts are a serving
  invariant (prefill executables are bounded by the bucket count), so a
  single new executable means some change reintroduced a retrace and is
  silently burning watts on XLA compilation instead of tokens, or
- ANY decrease in a row's ``hit_rate`` field — the prefix-cache hit rate on
  the shared-prefix workload is deterministic, so a drop means a sharing
  regression (trie matching, block refcounts, admission) is silently
  recomputing prefill work the cache used to serve for free, or
- ANY increase in a row's ``findings`` field — the ``repro.analysis``
  linter (``--gate-json``) emits one row per rule with its non-suppressed
  finding count; an increase means a new DLK violation landed without a
  pragma or a fix.

Rows carrying a ``compiles`` field are *only* gated on the compile count:
their wall time is cold-compile-dominated by design, which swings well past
any reasonable threshold across differently-provisioned CI runners with
zero code change. The deterministic count is the signal; the time is noise.

Rows present only in one file are reported but never fail the gate (new
benches must be able to land; deleted benches must not wedge CI forever).

    python -m benchmarks.regression_gate PREV.json CURRENT.json
    python -m benchmarks.regression_gate --prev-dir prev/ --cur-dir . \
        [--threshold 0.15] [--pattern "BENCH_*.json"]

Directory mode pairs files by basename, so one invocation gates every
artifact the CI perf-trajectory job uploads (serving, energy platform,
scheduler, roofline).
"""
import argparse
import glob
import json
import os
import sys

# rows cheaper than this are timer noise on shared CI runners; the compile
# gate still applies to them, only the slowdown check is skipped
MIN_GATED_US = 50.0


def load_rows(path):
    with open(path) as f:
        return json.load(f)


def diff_rows(name, prev, cur, threshold):
    """Compare one artifact's row dicts; returns a list of failure strings."""
    failures = []
    common = sorted(set(prev) & set(cur))
    for row in common:
        p, c = prev[row], cur[row]
        compile_row = "compiles" in p or "compiles" in c
        p_us, c_us = p.get("us_per_call", 0.0), c.get("us_per_call", 0.0)
        if (not compile_row and p_us >= MIN_GATED_US
                and c_us > p_us * (1.0 + threshold)):
            failures.append(
                f"{name}:{row}: {p_us:.1f}us -> {c_us:.1f}us "
                f"(+{(c_us / p_us - 1.0) * 100:.1f}% > "
                f"{threshold * 100:.0f}% threshold)")
        p_comp, c_comp = p.get("compiles"), c.get("compiles")
        if p_comp is not None and c_comp is not None and c_comp > p_comp:
            failures.append(
                f"{name}:{row}: compile count regressed "
                f"{p_comp} -> {c_comp} (any increase fails: a retrace "
                f"was reintroduced)")
        p_hit, c_hit = p.get("hit_rate"), c.get("hit_rate")
        if p_hit is not None and c_hit is not None and c_hit < p_hit - 1e-6:
            failures.append(
                f"{name}:{row}: prefix-cache hit rate regressed "
                f"{p_hit:.3f} -> {c_hit:.3f} (any decrease fails: a "
                f"sharing regression is recomputing cached prefill work)")
        p_find, c_find = p.get("findings"), c.get("findings")
        if p_find is not None and c_find is not None and c_find > p_find:
            failures.append(
                f"{name}:{row}: static-analysis findings regressed "
                f"{p_find} -> {c_find} (any increase fails: a new "
                f"dalek-lint violation landed without a fix or pragma)")
    for row in sorted(set(cur) - set(prev)):
        print(f"  [new row, not gated] {name}:{row}")
    for row in sorted(set(prev) - set(cur)):
        print(f"  [row disappeared, not gated] {name}:{row}")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="PREV.json CURRENT.json (file mode)")
    ap.add_argument("--prev-dir", default=None)
    ap.add_argument("--cur-dir", default=None)
    ap.add_argument("--pattern", default="BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max relative us_per_call slowdown (0.15 = 15%%)")
    args = ap.parse_args(argv)

    pairs = []
    if args.prev_dir and args.cur_dir:
        cur_files = sorted(glob.glob(os.path.join(args.cur_dir, args.pattern)))
        if not cur_files:
            print(f"no artifacts matching {args.pattern} in {args.cur_dir}")
            return 1
        for cur in cur_files:
            base = os.path.basename(cur)
            prev = os.path.join(args.prev_dir, base)
            if os.path.exists(prev):
                pairs.append((base, prev, cur))
            else:
                print(f"  [no previous artifact, not gated] {base}")
    elif len(args.files) == 2:
        pairs.append((os.path.basename(args.files[1]), *args.files))
    else:
        ap.error("pass PREV.json CURRENT.json or --prev-dir/--cur-dir")

    failures = []
    for name, prev, cur in pairs:
        print(f"gate: {prev} vs {cur}")
        failures += diff_rows(name, load_rows(prev), load_rows(cur),
                              args.threshold)

    if failures:
        print(f"\nREGRESSION GATE FAILED ({len(failures)}):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nregression gate passed ({len(pairs)} artifact(s), "
          f"threshold {args.threshold * 100:.0f}%, compile counts pinned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
