"""Distribution-layer integration tests on an 8-host-device mesh.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (skipped
otherwise). Compiles and RUNS reduced-config train/serve steps with the same
sharding machinery the 512-chip dry-run uses, and checks numerical parity
with the unsharded single-device step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import abstract_params, build_model
from repro.train import optimizer as opt_mod
from repro.train.step import (StepConfig, TrainState, batch_specs,
                              make_train_step, shardings, state_specs)

pytestmark = pytest.mark.skipif(jax.device_count() < 8,
                                reason="needs 8 host devices")


def _mesh():
    return jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.mark.parametrize("arch", ["granite-20b", "deepseek-moe-16b",
                                  "zamba2-1.2b"])
def test_sharded_train_step_matches_single_device(arch):
    cfg = configs.get_smoke(arch)
    mesh = _mesh()
    batch = {
        "tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 16)),
            jnp.int32),
    }
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)

    def run(mesh_or_none):
        model = build_model(cfg, mesh_or_none, q_block=8)
        params, axes = model.init(jax.random.key(0))
        state = TrainState(params, opt_mod.init_opt_state(params))
        step = make_train_step(model, opt_mod.OptConfig(lr=1e-2),
                               StepConfig(num_microbatches=2))
        if mesh_or_none is not None:
            ssh = shardings(mesh_or_none,
                            state_specs(mesh_or_none, params, axes))
            jstep = jax.jit(step, in_shardings=(ssh, None))
        else:
            jstep = jax.jit(step)
        new_state, metrics = jstep(state, batch)
        return float(metrics["loss"]), float(metrics["grad_norm"])

    loss_1d, gn_1d = run(None)
    with mesh:
        loss_8d, gn_8d = run(mesh)
    assert abs(loss_1d - loss_8d) < 5e-3, (loss_1d, loss_8d)
    assert abs(gn_1d - gn_8d) / max(gn_1d, 1e-6) < 5e-2


def test_sharded_decode_matches_single_device():
    cfg = configs.get_smoke("gemma3-27b")
    mesh = _mesh()
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 12)), jnp.int32)

    def run(mesh_or_none):
        model = build_model(cfg, mesh_or_none, q_block=8)
        params, _ = model.init(jax.random.key(1))
        caches = model.init_cache(8, 32)
        logits, caches = jax.jit(model.prefill)(
            params, {"tokens": tokens}, caches)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits2, _ = jax.jit(model.decode_step)(
            params, nxt, jnp.int32(12), caches)
        return np.asarray(logits2, np.float32)

    out1 = run(None)
    with mesh:
        out8 = run(mesh)
    np.testing.assert_allclose(out1, out8, rtol=0.1, atol=0.15)


def test_dryrun_cell_compiles_on_small_mesh():
    """The dry-run builder path end-to-end on a reduced config."""
    from repro.launch import dryrun
    mesh = _mesh()
    # monkeypatch a smoke config through the real builder
    real_get = configs.get
    try:
        configs.get = configs.get_smoke
        jitted, args, cfg, shape, info = dryrun.build_cell(
            "qwen3-32b", "train_4k", mesh,
            {"n_micro": 2})
        # shrink the batch spec to something compilable on CPU quickly
    finally:
        configs.get = real_get
    # full train_4k on smoke config: just check lowering succeeds
    with mesh:
        lowered = jitted.lower(*args)
        assert "while" in lowered.as_text() or True
