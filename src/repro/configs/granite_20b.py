"""granite-20b — llama-arch dense code model [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    source="arXiv:2405.04324",
)

SMOKE_CONFIG = CONFIG.replace(
    name="granite-20b-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=1, d_ff=256, vocab_size=512, head_dim=16,
)
