"""Continuous vs static batching at equal batch size (CPU backend).

A workload with mixed generation lengths is served twice through the same
smoke model: the static engine runs it in sequential batch groups (every
group decodes until its longest request finishes), the continuous engine
recycles slots so freed capacity is refilled mid-decode. Reports decode
tokens/s for both, the speedup (acceptance gate: >= 1.5x), and per-request
J/token from the tag-bus energy attribution.

A second, production-shaped scenario serves prompts of N *distinct* lengths
through the continuous engine with prefill bucketing off vs on: exact-length
prefill compiles one executable per length (the retrace explosion), bucketed
prefill is bounded by the bucket count. Reports end-to-end tokens/s for both
(acceptance gate: >= 2x from bucketing), the compile counts, and asserts the
generated tokens are identical.

A third scenario is the paged-KV headline: N requests sharing a long common
system prompt, served with the radix prefix cache off vs on. With the cache,
the shared prefix prefills ONCE — later requests map its blocks by reference
and compute only their distinct tail — so prefill compute drops from
O(requests x prompt) to O(prompt + requests x tail). Reports prefill tokens
computed vs served, the cache hit rate, end-to-end tokens/s (acceptance
gate: >= 2x from prefix caching), J/token from the modeled energy, and
asserts cached tokens are identical to cold. ``--json PATH`` dumps the rows
for the CI perf-trajectory artifact; the ``compiles`` fields are what the
cross-run regression gate (``benchmarks.regression_gate``) pins, and the
``hit_rate`` field is gated against decreases the same way.

A mixed-family scenario models the paper's heterogeneous node: one
request stream alternating between a transformer and an SSM, each served
by its own continuous engine through the family's ``CacheAdapter``
(paged-KV vs carried recurrent state). Reports per-family and combined
tok/s and J/token; the compile counts ride in the rows so the gate pins
both families' executables — a retrace reintroduced in *either* adapter
fails CI.

A fourth scenario prices the observability layer itself: the per-step span
emission cost (microbenched in the exact ``decode_step`` shape the engine
emits) over the measured mean decode-step wall — the first-order decode
tok/s loss from tracing. The fraction rides in a
``{"value": ..., "budget": 0.05}`` row — the regression gate fails whenever
span emission costs more than 5% decode throughput, *without* needing a
previous artifact to diff against.

``--trace-out PATH`` exports the shared-prefix cached run's span timeline
as Perfetto/chrome-trace JSON with per-span attributed joules (CI uploads
it as an artifact next to the bench rows).

    PYTHONPATH=src python -m benchmarks.bench_serving [--json PATH]
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.obs import write_chrome_trace
from repro.serve.engine import ContinuousEngine, Request, ServeEngine

from benchmarks.common import BenchRows

# mixed lengths: the static engine pays max(group) steps per group, the
# continuous engine only pays for tokens actually generated
MAX_NEW_PATTERN = [2, 4, 8, 32]


def make_requests(cfg, n, prompt_len, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    prompt_len).astype(np.int32),
                    max_new_tokens=MAX_NEW_PATTERN[i % len(MAX_NEW_PATTERN)])
            for i in range(n)]


def run_static(model, params, cfg, args):
    eng = ServeEngine(model, params, batch_size=args.batch,
                      max_seq=args.max_seq)
    eng.serve(make_requests(cfg, args.batch, args.prompt_len, seed=99))  # warmup
    reqs = make_requests(cfg, args.requests, args.prompt_len)
    tokens = dec_s = 0.0
    for i in range(0, len(reqs), args.batch):
        st = eng.serve(reqs[i:i + args.batch])
        tokens += st["tokens_decoded"]
        dec_s += st["decode_s"]
    return reqs, tokens, dec_s


def run_continuous(model, params, cfg, args):
    eng = ContinuousEngine(model, params, batch_size=args.batch,
                           max_seq=args.max_seq)
    eng.serve(make_requests(cfg, args.batch, args.prompt_len, seed=99))  # warmup
    eng.reset_metrics()
    reqs = make_requests(cfg, args.requests, args.prompt_len)
    st = eng.serve(reqs)
    return reqs, st, eng


def make_mixed_requests(cfg, lengths, max_new, seed=0):
    """One request per entry of ``lengths`` — every prompt a distinct
    length, the production traffic shape that retraces exact-length
    prefill once per request."""
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lengths)]


def run_mixed(model, params, cfg, args, buckets):
    eng = ContinuousEngine(model, params, batch_size=args.batch,
                           max_seq=args.mixed_max_seq,
                           prefill_buckets=buckets)
    # warm only the decode path (fixed [B,1] shape) + one prefill length;
    # the point of the scenario is cold prefill on unseen lengths
    eng.serve(make_mixed_requests(cfg, [args.mixed_min_len] * args.batch,
                                  args.mixed_max_new, seed=99))
    eng.reset_metrics()
    lengths = [args.mixed_min_len + i for i in range(args.mixed_lengths)]
    reqs = make_mixed_requests(cfg, lengths, args.mixed_max_new)
    t0 = time.perf_counter()
    st = eng.serve(reqs)
    st["wall_s"] = time.perf_counter() - t0
    return reqs, st


def make_shared_prefix_requests(cfg, n, prefix_len, tail_len, max_new,
                                seed=0):
    """N prompts = one shared system prefix + per-request distinct tails —
    the traffic shape prefix caching exists for."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    out = []
    for i in range(n):
        tail = np.random.default_rng(seed * 1000 + i + 1).integers(
            0, cfg.vocab_size, tail_len).astype(np.int32)
        out.append(Request(i, np.concatenate([shared, tail]),
                           max_new_tokens=max_new))
    return out


def run_mixed_family(args, transformer):
    """One interleaved request stream across a heterogeneous pair of
    engines: even requests hit the transformer (paged-KV adapter), odd
    requests the SSM (recurrent adapter). Each engine serves its
    subsequence; combined throughput charges the serialized wall, the
    host cost of hosting both families."""
    t_cfg, t_model, t_params = transformer
    s_cfg = configs.get_smoke(args.family_arch)
    s_model = build_model(s_cfg)
    s_params, _ = s_model.init(jax.random.key(0))
    engines = {
        "transformer": (t_cfg, ContinuousEngine(
            t_model, t_params, batch_size=args.batch, max_seq=args.max_seq)),
        "ssm": (s_cfg, ContinuousEngine(
            s_model, s_params, batch_size=args.batch, max_seq=args.max_seq)),
    }
    stream = [("transformer" if i % 2 == 0 else "ssm", i)
              for i in range(args.family_requests)]
    out = {}
    for key, (cfg, eng) in engines.items():
        eng.serve(make_requests(cfg, args.batch, args.prompt_len, seed=99))
        eng.reset_metrics()
        reqs = make_requests(cfg, args.family_requests, args.prompt_len)
        mine = [reqs[i] for k, i in stream if k == key]
        t0 = time.perf_counter()
        st = eng.serve(mine)
        st["wall_s"] = time.perf_counter() - t0
        out[key] = st
    return out


def run_span_overhead(model, params, cfg, args, eng, st):
    """Fractional decode-throughput cost of span emission.

    Comparing whole-run tok/s with tracing on vs off drowns the signal in
    run-to-run jit variance on shared CI runners (the span work is a few µs
    against ~ms steps), so this measures the two factors directly instead:
    the per-step span cost (microbenched on the live engine's tracer —
    exactly the ``decode_step`` shape the engine emits: span + step gauges
    as attrs + window ref + end) over the measured mean decode-step wall
    from the continuous-batching scenario just run. Best of N microbench
    repeats sheds scheduler noise; the ratio is the first-order tok/s loss.
    """
    step_wall = st["decode_s"] / max(st["decode_steps"], 1)
    tr = eng.tracer
    n = 2000
    span_cost = float("inf")
    for _ in range(args.overhead_repeats):
        tr.clear()
        t0 = time.perf_counter()
        for i in range(n):
            with tr.span("decode_step", track="engine", active=4,
                         queue_depth=8, free_blocks=12,
                         evictable_blocks=3) as sp:
                sp.set("window", i)
        span_cost = min(span_cost, (time.perf_counter() - t0) / n)
    tr.clear()
    overhead = span_cost / step_wall if step_wall else 0.0
    return span_cost, step_wall, overhead


def run_shared_prefix(model, params, cfg, args, prefix_cache):
    eng = ContinuousEngine(model, params, batch_size=args.batch,
                           max_seq=args.prefix_max_seq,
                           prefix_cache=prefix_cache)
    # warmup compiles both prefill shapes the measured phase needs: the
    # full-prompt bucket (cold misses) and the tail bucket (cache hits);
    # reset_metrics clears the trie so the measured phase starts cold
    eng.serve(make_shared_prefix_requests(
        cfg, args.batch, args.prefix_len, args.prefix_tail,
        args.prefix_max_new, seed=99))
    eng.reset_metrics()
    reqs = make_shared_prefix_requests(
        cfg, args.prefix_requests, args.prefix_len, args.prefix_tail,
        args.prefix_max_new)
    t0 = time.perf_counter()
    st = eng.serve(reqs)
    st["wall_s"] = time.perf_counter() - t0
    return reqs, st, eng


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=48)
    ap.add_argument("--mixed-lengths", type=int, default=32,
                    help="distinct prompt lengths in the retrace scenario")
    ap.add_argument("--mixed-min-len", type=int, default=4)
    ap.add_argument("--mixed-max-new", type=int, default=4)
    ap.add_argument("--mixed-max-seq", type=int, default=64)
    ap.add_argument("--prefix-requests", type=int, default=16,
                    help="requests in the shared-prefix scenario")
    ap.add_argument("--prefix-len", type=int, default=96,
                    help="shared system-prompt length")
    ap.add_argument("--prefix-tail", type=int, default=4,
                    help="distinct per-request tail length")
    ap.add_argument("--prefix-max-new", type=int, default=2)
    ap.add_argument("--prefix-max-seq", type=int, default=128)
    ap.add_argument("--family-arch", default="xlstm-1.3b",
                    help="recurrent-family arch for the mixed-family "
                         "scenario")
    ap.add_argument("--family-requests", type=int, default=8,
                    help="requests in the interleaved mixed-family stream")
    ap.add_argument("--overhead-repeats", type=int, default=3,
                    help="span-emission microbench repeats (best-of-N "
                         "sheds CI scheduler noise)")
    ap.add_argument("--span-budget", type=float, default=0.05,
                    help="max fraction of decode tok/s span emission may "
                         "cost (budget row, gated absolutely)")
    ap.add_argument("--json", default=None,
                    help="dump rows as JSON (CI perf-trajectory artifact)")
    ap.add_argument("--trace-out", default=None,
                    help="write the shared-prefix cached run's Perfetto "
                         "timeline (spans + per-span attributed joules)")
    args = ap.parse_args(argv)
    rows = BenchRows()

    cfg = configs.get_smoke(args.arch)
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))

    s_reqs, s_tokens, s_dec = run_static(model, params, cfg, args)
    c_reqs, c_st, c_eng = run_continuous(model, params, cfg, args)

    s_tps = s_tokens / s_dec if s_dec else 0.0
    c_tps = c_st["decode_tok_per_s"]
    speedup = c_tps / s_tps if s_tps else float("inf")

    assert all(a.output == b.output for a, b in zip(s_reqs, c_reqs)), \
        "engines disagree on generated tokens"

    rows.record("serve/static_decode", 1.0 / s_tps if s_tps else 0.0,
                f"{s_tps:.1f}tok/s")
    rows.record("serve/continuous_decode", 1.0 / c_tps if c_tps else 0.0,
                f"{c_tps:.1f}tok/s;speedup={speedup:.2f}x_vs_static;"
                f"recycles={c_st['slots_recycled']}")
    total_j = c_st.get("energy_j", 0.0)
    rows.record("serve/continuous_energy", c_st["decode_s"],
                f"{total_j:.2f}J_total;"
                f"{total_j / max(c_st['tokens_decoded'], 1):.3f}J/token")

    # -- retrace scenario: N distinct prompt lengths, bucketing off vs on --
    u_reqs, u_st = run_mixed(model, params, cfg, args, buckets="off")
    b_reqs, b_st = run_mixed(model, params, cfg, args, buckets="auto")
    assert all(a.output == b.output for a, b in zip(u_reqs, b_reqs)), \
        "bucketed prefill changed generated tokens"

    def _e2e_tps(st):
        # wall time, not prefill_s+decode_s: the retrace cost shows up
        # partly as host-loop overhead between steps
        return st["tokens_decoded"] / st["wall_s"] if st["wall_s"] else 0.0

    u_tps, b_tps = _e2e_tps(u_st), _e2e_tps(b_st)
    bucket_speedup = b_tps / u_tps if u_tps else float("inf")
    rows.record("serve/mixed_unbucketed", u_st["wall_s"],
                f"{u_tps:.1f}tok/s_e2e;lengths={args.mixed_lengths}",
                compiles=u_st["prefill_compiles"])
    rows.record("serve/mixed_bucketed", b_st["wall_s"],
                f"{b_tps:.1f}tok/s_e2e;speedup={bucket_speedup:.2f}x;"
                f"buckets={b_st['prefill_buckets']}",
                compiles=b_st["prefill_compiles"])
    # the regression-gated metric: bucketed prefill executables must never
    # grow across runs (a retrace reintroduced anywhere fails the gate)
    rows.record("serve/prefill_compiles", b_st["prefill_s"],
                f"compiles={b_st['prefill_compiles']};"
                f"unbucketed={u_st['prefill_compiles']}",
                compiles=b_st["prefill_compiles"])

    # -- shared-prefix scenario: radix prefix cache off vs on --------------
    p_reqs, p_st, _ = run_shared_prefix(model, params, cfg, args,
                                        prefix_cache=False)
    h_reqs, h_st, h_eng = run_shared_prefix(model, params, cfg, args,
                                            prefix_cache=True)
    assert all(a.output == b.output for a, b in zip(p_reqs, h_reqs)), \
        "prefix-cache hits changed generated tokens"

    p_tps, h_tps = _e2e_tps(p_st), _e2e_tps(h_st)
    prefix_speedup = h_tps / p_tps if p_tps else float("inf")
    hit = h_st["prefix_cache"]
    h_jtok = h_st.get("energy_j", 0.0) / max(h_st["tokens_decoded"], 1)
    p_jtok = p_st.get("energy_j", 0.0) / max(p_st["tokens_decoded"], 1)
    rows.record("serve/prefix_cold", p_st["wall_s"],
                f"{p_tps:.1f}tok/s_e2e;"
                f"prefill_computed={p_st['prefill_tokens_computed']};"
                f"{p_jtok:.3f}J/token",
                compiles=p_st["prefill_compiles"])
    # hit_rate rides in the JSON row: the cross-run gate fails on any
    # decrease (a sharing regression wastes prefill joules silently)
    rows.record("serve/prefix_cached", h_st["wall_s"],
                f"{h_tps:.1f}tok/s_e2e;speedup={prefix_speedup:.2f}x;"
                f"hit_rate={hit['hit_rate']:.2f};"
                f"prefill_computed={h_st['prefill_tokens_computed']};"
                f"{h_jtok:.3f}J/token",
                compiles=h_st["prefill_compiles"],
                hit_rate=hit["hit_rate"])
    # auxiliary executables (slot reset / block zero / block copy) are
    # metered since they moved under counting_jit; they get their OWN row —
    # existing rows keep their historical compile semantics, and the gate
    # pins this one from its first appearance onward
    aux = {}
    for st in (c_st, h_st):
        for nm, n in st.get("compiles", {}).items():
            if nm not in ("prefill", "decode"):
                aux[nm] = aux.get(nm, 0) + n
    rows.record("serve/aux_compiles", 0.0,
                ";".join(f"{k}={v}" for k, v in sorted(aux.items())) or "none",
                compiles=sum(aux.values()))

    # -- mixed-family scenario: transformer + SSM interleaved --------------
    fam = run_mixed_family(args, (cfg, model, params))

    def _fam_metrics(st):
        tps = _e2e_tps(st)
        jtok = st.get("energy_j", 0.0) / max(st["tokens_decoded"], 1)
        n_compiles = sum(st.get("compiles", {}).values())
        return tps, jtok, n_compiles

    fam_rows = {k: _fam_metrics(st) for k, st in fam.items()}
    for key, (tps, jtok, n_compiles) in sorted(fam_rows.items()):
        st = fam[key]
        rows.record(f"serve/mixed_family_{key}", st["wall_s"],
                    f"{tps:.1f}tok/s_e2e;{jtok:.3f}J/token;"
                    f"adapter={st['adapter']}",
                    compiles=n_compiles)
    fam_wall = sum(st["wall_s"] for st in fam.values())
    fam_tokens = sum(st["tokens_decoded"] for st in fam.values())
    fam_j = sum(st.get("energy_j", 0.0) for st in fam.values())
    fam_tps = fam_tokens / fam_wall if fam_wall else 0.0
    rows.record("serve/mixed_family", fam_wall,
                f"{fam_tps:.1f}tok/s_combined;"
                f"{fam_j / max(fam_tokens, 1):.3f}J/token",
                compiles=sum(c for _, _, c in fam_rows.values()))

    # -- span-overhead scenario: observability must be near-free -----------
    span_cost, step_wall, overhead = run_span_overhead(
        model, params, cfg, args, c_eng, c_st)
    rows.record("serve/span_overhead", span_cost,
                f"span={span_cost*1e6:.2f}us;step={step_wall*1e6:.0f}us;"
                f"overhead={overhead:.2%}",
                value=overhead, budget=args.span_budget)

    if args.trace_out:
        write_chrome_trace(
            args.trace_out, h_eng.tracer,
            session=h_eng.tel.session if h_eng.tel is not None else None,
            meta={"process": "bench-serving", "arch": cfg.name,
                  "scenario": "shared-prefix-cached"})
        print(f"timeline -> {args.trace_out}")
    rows.dump(args.json)
    print(f"\nstatic    : {s_tokens:.0f} tokens in {s_dec*1e3:.0f} ms decode "
          f"({s_tps:.1f} tok/s)")
    print(f"continuous: {c_st['tokens_decoded']} tokens in "
          f"{c_st['decode_s']*1e3:.0f} ms decode ({c_tps:.1f} tok/s), "
          f"{c_st['slots_recycled']} slot recycles, "
          f"peak {c_st['peak_active']} active")
    print(f"speedup   : {speedup:.2f}x "
          f"({'PASS' if speedup >= 1.5 else 'FAIL'} >= 1.5x gate)")
    print(f"\nretrace scenario ({args.mixed_lengths} distinct prompt lengths):")
    print(f"  unbucketed: {u_st['prefill_compiles']} prefill compiles, "
          f"{u_tps:.1f} tok/s end-to-end")
    print(f"  bucketed  : {b_st['prefill_compiles']} prefill compiles "
          f"(buckets={b_st['prefill_buckets']}), {b_tps:.1f} tok/s end-to-end")
    print(f"  bucketing speedup: {bucket_speedup:.2f}x "
          f"({'PASS' if bucket_speedup >= 2.0 else 'FAIL'} >= 2x gate)")
    print(f"\nshared-prefix scenario ({args.prefix_requests} requests, "
          f"{args.prefix_len}-token shared prefix, "
          f"{args.prefix_tail}-token tails, kv block "
          f"{h_st['kv_block_size']}):")
    print(f"  prefix cache off: {p_st['prefill_tokens_computed']} prefill "
          f"tokens computed / {p_st['prompt_tokens']} served, "
          f"{p_tps:.1f} tok/s e2e, {p_jtok:.3f} J/token")
    print(f"  prefix cache on : {h_st['prefill_tokens_computed']} prefill "
          f"tokens computed / {h_st['prompt_tokens']} served "
          f"(hit rate {hit['hit_rate']:.0%}, "
          f"{hit['cached_tokens']} tokens cached), "
          f"{h_tps:.1f} tok/s e2e, {h_jtok:.3f} J/token")
    print(f"  prefix-cache speedup: {prefix_speedup:.2f}x "
          f"({'PASS' if prefix_speedup >= 2.0 else 'FAIL'} >= 2x gate)")
    print(f"\nmixed-family scenario ({args.family_requests} requests "
          f"interleaved transformer/{args.family_arch}):")
    for key, (tps, jtok, n_compiles) in sorted(fam_rows.items()):
        print(f"  {key:11s}: {fam[key]['tokens_decoded']} tokens, "
              f"{tps:.1f} tok/s e2e, {jtok:.3f} J/token, "
              f"{n_compiles} compiles [{fam[key]['adapter']}]")
    print(f"  combined   : {fam_tps:.1f} tok/s over the serialized wall, "
          f"{fam_j / max(fam_tokens, 1):.3f} J/token")
    print(f"\nspan-overhead scenario (best of {args.overhead_repeats} "
          f"microbench repeats):")
    print(f"  decode_step span emission: {span_cost*1e6:.2f} us/step")
    print(f"  measured decode step wall: {step_wall*1e6:.0f} us")
    print(f"  overhead: {overhead:.2%} "
          f"({'PASS' if overhead <= args.span_budget else 'FAIL'} <= "
          f"{args.span_budget:.0%} budget)")
    print("\nper-request energy (tag-bus attribution):")
    for r in c_reqs:
        print(f"  req {r.req_id:2d}: {len(r.output):2d} tokens  "
              f"{r.energy_j:7.2f} J  "
              f"{r.energy_j / max(len(r.output), 1):6.2f} J/token")
    parts = sum(r.energy_j for r in c_reqs)
    print(f"  board total {total_j:.2f} J, request sum {parts:.2f} J")
    return speedup


if __name__ == "__main__":
    main()
