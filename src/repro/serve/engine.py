"""Batched serving engine: continuous-batching-lite over prefill/decode.

Requests queue up; the engine prefills them (padded into the fixed batch),
then decodes in lock-step with per-slot stop handling. Energy per request is
attributed via the telemetry tag bus (the paper's GPIO tagging, Sec. 4.1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mainboard import MainBoard
from repro.core.probe import Probe


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 telemetry: bool = True):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self.board = MainBoard("serve-node") if telemetry else None
        self.samples = []
        if self.board:
            self._power = 10.0
            self.board.attach(Probe(lambda t: self._power))

    def _pad_prompts(self, reqs: List[Request]):
        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.batch_size, s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):] = r.prompt   # left-pad
        return jnp.asarray(toks), s

    def serve(self, reqs: List[Request]) -> Dict:
        """One batch generation pass; returns stats."""
        assert len(reqs) <= self.batch_size
        pad = [Request(-1, reqs[0].prompt, 0) for _ in
               range(self.batch_size - len(reqs))]
        batch_reqs = reqs + pad
        tokens, s = self._pad_prompts(batch_reqs)
        caches = self.model.init_cache(self.batch_size, self.max_seq)

        t0 = time.perf_counter()
        if self.board:
            self.board.tags.raise_("prefill")
        logits, caches = self._prefill(self.params, {"tokens": tokens}, caches)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        if self.board:
            self._power = 80.0
            self.samples.extend(self.board.read_samples(t_prefill)[0])
            self.board.tags.lower("prefill")

        max_new = max(r.max_new_tokens for r in reqs)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # [B,1]
        n_decoded = 0
        t_dec = 0.0
        for i in range(max_new):
            for bi, r in enumerate(reqs):
                if not r.done and r.max_new_tokens > len(r.output):
                    tok = int(cur[bi, 0])
                    r.output.append(tok)
                    if r.eos_id is not None and tok == r.eos_id:
                        r.done = True
                elif not r.done:
                    r.done = True
            if all(r.done for r in reqs):
                break
            td0 = time.perf_counter()
            if self.board:
                self.board.tags.raise_("decode")
            logits, caches = self._decode(self.params, cur,
                                          jnp.int32(s + i), caches)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            jax.block_until_ready(cur)
            dt = time.perf_counter() - td0
            t_dec += dt
            n_decoded += sum(1 for r in reqs if not r.done)
            if self.board:
                self._power = 40.0
                self.samples.extend(self.board.read_samples(dt)[0])
                self.board.tags.lower("decode")

        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_dec,
            "tokens_decoded": n_decoded,
            "decode_tok_per_s": n_decoded / t_dec if t_dec else 0.0,
        }
        if self.board:
            stats["energy_j"] = MainBoard.energy_j(self.samples)
            stats["energy_by_tag"] = MainBoard.energy_by_tag(self.samples)
        return stats
