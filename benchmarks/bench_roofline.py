"""§Roofline report: reads the dry-run records (results/dryrun/*) and prints
the three-term roofline per (arch x shape x mesh) + J/token from the energy
model. This is the table EXPERIMENTS.md §Roofline embeds. ``--json PATH``
dumps the rows for the CI perf-trajectory artifact (empty when no dry-run
records exist — the artifact still marks the bench as having run).

    PYTHONPATH=src python -m benchmarks.bench_roofline [--json PATH]
"""
import argparse
import json
import pathlib

from benchmarks.common import BenchRows
from repro.core import energy

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh="single"):
    out = []
    d = RESULTS / mesh
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if "roofline" in rec:
            out.append(rec)
    return out


def run(json_path=None):
    rows = BenchRows()
    for mesh in ("single", "multi"):
        for rec in load(mesh):
            rl = rec["roofline"]
            terms = {"compute": rl["compute_s"], "memory": rl["memory_s"],
                     "collective": rl["collective_s"]}
            t_step = energy.step_time_s(terms)
            e_step = energy.step_energy_j(terms) * rec["n_chips"]
            shape_tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                            "decode_32k": 128, "long_500k": 1}
            tokens = shape_tokens.get(rec["shape"], 1)
            jpt = e_step / tokens
            frac = rl["compute_s"] / max(t_step, 1e-12)
            rows.record(f"roofline/{mesh}/{rec['arch']}/{rec['shape']}",
                        t_step,
                        f"dom={rl['dominant']};roofline_frac={frac:.3f};"
                        f"useful={rl['useful_ratio']:.2f};"
                        f"hbm={rec.get('hbm_per_device_gb', 0):.1f}GiB;"
                        f"J/tok={jpt:.4g}")
    rows.dump(json_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="dump rows as JSON (CI perf-trajectory artifact)")
    run(ap.parse_args().json)
