"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    num_experts=64, experts_per_token=6, num_shared_experts=2,
    moe_d_ff=1408, first_k_dense=1,
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE_CONFIG = CONFIG.replace(
    name="moonshot-v1-16b-a3b-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=8, d_ff=64, vocab_size=512, head_dim=16,
    num_experts=8, experts_per_token=2, num_shared_experts=1, moe_d_ff=64,
)
