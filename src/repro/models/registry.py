"""Model registry: config -> model instance + abstract input specs.

``input_specs(cfg, shape, mesh)`` returns ShapeDtypeStruct stand-ins for every
model input of a given assigned shape cell — weak-type-correct, shardable, no
device allocation — consumed by the multi-pod dry-run.

``serving_caps(cfg)`` declares what the serving stack may do with a family —
the engines and ``serve/state.py`` adapters consult these flags instead of
``inspect.signature`` sniffing on model methods.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.mamba2 import Zamba2
from repro.models.transformer import DecoderLM
from repro.models.whisper import Whisper
from repro.models.xlstm import XLSTM


@dataclasses.dataclass(frozen=True)
class ServingCaps:
    """Declared serving capabilities for one model family.

    ``kind`` names the ``CacheAdapter`` backend that owns per-slot state:
    ``paged-kv`` (flat (k, v) layer caches behind a refcounted PagePool),
    ``window-ring`` (gemma3 local:global ring caches, contiguous slots), or
    ``recurrent`` (carried state gather/scatter/reset + chunked prefill).
    """

    family: str
    kind: str                      # paged-kv | window-ring | recurrent
    bucketed_prefill: bool         # right-pad to pow2 bucket + true_len mask
    paged_kv: bool                 # PagePool block indirection
    prefix_cache: bool             # radix trie sharing (requires paged_kv)
    chunked_prefill: bool          # left-to-right start_pos chunk resume
    needs_frames: bool = False     # audio: requests carry encoder frames


def serving_caps(cfg: ModelConfig) -> ServingCaps:
    """Declared capability flags for ``cfg``'s family (no model needed)."""
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.local_global_period > 0:
            # gemma3-style local:global — ring caches can't page (yet)
            return ServingCaps(cfg.family, "window-ring",
                               bucketed_prefill=True, paged_kv=False,
                               prefix_cache=False, chunked_prefill=False)
        return ServingCaps(cfg.family, "paged-kv",
                           bucketed_prefill=True, paged_kv=True,
                           prefix_cache=True, chunked_prefill=True)
    if cfg.family in ("ssm", "hybrid"):
        return ServingCaps(cfg.family, "recurrent",
                           bucketed_prefill=False, paged_kv=False,
                           prefix_cache=False, chunked_prefill=True)
    if cfg.family == "audio":
        return ServingCaps(cfg.family, "recurrent",
                           bucketed_prefill=False, paged_kv=False,
                           prefix_cache=False, chunked_prefill=True,
                           needs_frames=True)
    raise ValueError(f"unknown family {cfg.family}")


def build_model(cfg: ModelConfig, mesh=None, **kw):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, mesh, **kw)
    if cfg.family == "ssm":
        return XLSTM(cfg, mesh, **kw)
    if cfg.family == "hybrid":
        return Zamba2(cfg, mesh, **kw)
    if cfg.family == "audio":
        return Whisper(cfg, mesh, **kw)
    raise ValueError(f"unknown family {cfg.family}")


def abstract_params(model):
    """(ShapeDtypeStruct params tree, logical-axes tree) without allocation."""
    return model.init(None)  # ParamBuilder abstract mode


def token_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Training/prefill batch ShapeDtypeStructs for this arch."""
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeddings"] = jax.ShapeDtypeStruct(
            (b, cfg.stub_prefix_len, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return batch
