"""Pin the regression_gate new-row convention: a row that appears for the
first time (e.g. analysis/DLK009..012 landing with a new rule) is printed
but NOT gated; once present in both snapshots, any findings increase fails.
"""
import importlib.util
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "regression_gate", REPO / "benchmarks" / "regression_gate.py")
regression_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(regression_gate)


def _write(path, rows):
    path.write_text(json.dumps(rows))
    return str(path)


def test_new_analysis_rows_are_ungated(tmp_path, capsys):
    # previous snapshot predates the interprocedural rules; their first
    # appearance — even with nonzero findings — must not fail the gate
    prev = _write(tmp_path / "prev.json", {
        "analysis/DLK001": {"findings": 0},
    })
    cur = _write(tmp_path / "cur.json", {
        "analysis/DLK001": {"findings": 0},
        "analysis/DLK009": {"findings": 3},
        "analysis/DLK010": {"findings": 1},
        "analysis/DLK011": {"findings": 2},
        "analysis/DLK012": {"findings": 5},
    })
    assert regression_gate.main([prev, cur]) == 0
    out = capsys.readouterr().out
    assert "not gated" in out and "DLK009" in out


def test_findings_increase_on_pinned_row_fails(tmp_path):
    # once a rule's row exists in the previous snapshot it is pinned:
    # any increase in findings fails the gate
    prev = _write(tmp_path / "prev.json", {
        "analysis/DLK009": {"findings": 0},
    })
    cur = _write(tmp_path / "cur.json", {
        "analysis/DLK009": {"findings": 2},
    })
    assert regression_gate.main([prev, cur]) == 1


def test_findings_decrease_or_equal_passes(tmp_path):
    prev = _write(tmp_path / "prev.json", {
        "analysis/DLK009": {"findings": 2},
        "analysis/DLK012": {"findings": 4},
    })
    cur = _write(tmp_path / "cur.json", {
        "analysis/DLK009": {"findings": 0},
        "analysis/DLK012": {"findings": 4},
    })
    assert regression_gate.main([prev, cur]) == 0
