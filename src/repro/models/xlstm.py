"""xLSTM (arXiv:2405.04517): mLSTM (matrix-memory, chunkwise-parallel) +
sLSTM (scalar-memory, time-recurrent) blocks.

Layer pattern: every ``cfg.slstm_every``-th block is sLSTM, the rest mLSTM
(7:1 for the assigned xlstm-1.3b). mLSTM layers are scanned in homogeneous
groups; sLSTM layers are unrolled between groups.

Numerics: gates computed in fp32; the input gate pre-activation is clamped
(soft capacity for the exponential gate) instead of carrying the xLSTM
paper's running-max stabilizer — the chunkwise and recurrent forms then agree
exactly, which the property tests assert.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import ParamBuilder
from repro.parallel.sharding import Sharder

I_CLAMP = 8.0  # clamp on input-gate pre-activation (exp gate)


def _ffn_width(d):  # llama-style 8/3 rounded to 64
    return int(np.ceil(8 * d / 3 / 64) * 64)


def mlstm_init(pb: ParamBuilder, cfg: ModelConfig, L: Optional[int]):
    d = cfg.d_model
    di = 2 * d                       # up-projection factor 2
    nh = cfg.num_heads
    pre = (L,) if L is not None else ()
    pax = ("layers",) if L is not None else ()
    pb.dense("norm", pre + (d,), pax + ("norm",), zero=True)
    pb.dense("w_up", pre + (d, 2 * di), pax + ("embed", "ssm_inner"), fan_in=d)
    pb.dense("conv", pre + (4, di), pax + ("conv_width", "ssm_inner"), fan_in=4)
    pb.dense("wq", pre + (di, di), pax + ("ssm_inner", None), fan_in=di)
    pb.dense("wk", pre + (di, di), pax + ("ssm_inner", None), fan_in=di)
    pb.dense("wv", pre + (di, di), pax + ("ssm_inner", None), fan_in=di)
    pb.dense("w_gates", pre + (di, 2 * nh), pax + ("ssm_inner", None), fan_in=di)
    pb.dense("b_gates", pre + (2 * nh,), pax + (None,), zero=True)
    pb.dense("out_norm", pre + (di,), pax + ("ssm_inner",), zero=True)
    pb.dense("w_down", pre + (di, d), pax + ("ssm_inner", "embed"), fan_in=di)


def _causal_conv(x, w, state=None):
    """x: [B,T,C], w: [W,C] depthwise. state: [B,W-1,C] carried for decode."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(width))
    new_state = xp[:, -(width - 1):]
    if state is not None:
        # Keep the carried-state dtype stable across steps: init_cache
        # allocates float32, and a drifting dtype changes the abstract
        # signature of the fused decode step, forcing a retrace.
        new_state = new_state.astype(state.dtype)
    return out, new_state


def _mlstm_gates(xi, p, nh):
    g = jnp.einsum("btc,ch->bth", xi, p["w_gates"].astype(xi.dtype))
    g = (g + p["b_gates"].astype(xi.dtype)).astype(jnp.float32)
    logi = jnp.minimum(g[..., :nh], I_CLAMP)            # [B,T,NH]
    logf = jax.nn.log_sigmoid(g[..., nh:])              # [B,T,NH] <= 0
    return logi, logf


def mlstm_chunkwise(q, k, v, logi, logf, state, chunk=256):
    """Chunkwise-parallel mLSTM. q,k,v: [B,T,NH,dh]; logi/logf: [B,T,NH].

    state: (C [B,NH,dh,dh], n [B,NH,dh]); returns (h, new_state).
    Sub-quadratic: O(T*chunk) intra + O(T/chunk) state passes.
    """
    b, t, nh, dh = q.shape
    w = min(chunk, t)
    assert t % w == 0, (t, w)
    nc = t // w
    scale = 1.0 / np.sqrt(dh)

    def reshape(x):
        return x.reshape(b, nc, w, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = reshape(q), reshape(k), reshape(v)       # [NC,B,W,NH,dh]
    lis, lfs = reshape(logi), reshape(logf)               # [NC,B,W,NH]

    def body(carry, inp):
        C, n = carry                                      # fp32
        qc, kc, vc, li, lf = inp
        qf = qc.astype(jnp.float32) * scale
        kf, vf = kc.astype(jnp.float32), vc.astype(jnp.float32)
        lc = jnp.cumsum(lf, axis=1)                       # [B,W,NH] inclusive
        ltot = lc[:, -1]                                  # [B,NH]
        # intra-chunk: decay matrix A[t,s] = exp(lc_t - lc_s + li_s), s<=t
        dm = lc[:, :, None, :] - lc[:, None, :, :] + li[:, None, :, :]
        mask = jnp.tril(jnp.ones((w, w), bool))
        A = jnp.where(mask[None, :, :, None], jnp.exp(dm), 0.0)  # [B,W,W,NH]
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * A
        num_intra = jnp.einsum("btsh,bshd->bthd", scores, vf)
        den_intra = jnp.sum(scores, axis=2)               # [B,W,NH]
        # inter-chunk: carried state decayed to each position
        decay_t = jnp.exp(lc)                             # [B,W,NH]
        num_inter = jnp.einsum("bthd,bhde->bthe", qf, C) * decay_t[..., None]
        den_inter = jnp.einsum("bthd,bhd->bth", qf, n) * decay_t
        den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
        h = (num_intra + num_inter) / den[..., None]
        # state update: C' = exp(ltot) C + sum_s exp(ltot - lc_s + li_s) k v^T
        sdecay = jnp.exp(ltot[:, None] - lc + li)         # [B,W,NH]
        C = jnp.exp(ltot)[:, :, None, None] * C + jnp.einsum(
            "bshd,bshe,bsh->bhde", kf, vf, sdecay)
        n = jnp.exp(ltot)[..., None] * n + jnp.einsum("bshd,bsh->bhd", kf, sdecay)
        return (C, n), h

    (C, n), hs = lax.scan(body, state, (qs, ks, vs, lis, lfs))
    h = hs.swapaxes(0, 1).reshape(b, t, nh, dh)
    return h, (C, n)


def mlstm_step(q, k, v, logi, logf, state):
    """Single-token recurrence. q,k,v: [B,1,NH,dh]."""
    C, n = state
    dh = q.shape[-1]
    qf = q[:, 0].astype(jnp.float32) / np.sqrt(dh)        # [B,NH,dh]
    kf, vf = k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
    i_ = jnp.exp(logi[:, 0])                              # [B,NH]
    f_ = jnp.exp(logf[:, 0])
    C = f_[..., None, None] * C + i_[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = f_[..., None] * n + i_[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
    return (num / den[..., None])[:, None], (C, n)


def mlstm_block(x, p, cfg: ModelConfig, shd: Sharder, state, *, chunk=256):
    """state: (C, n, conv_state) or None (training, zero-init)."""
    b, t, d = x.shape
    di, nh = 2 * d, cfg.num_heads
    dh = di // nh
    y = common.rms_norm(x, p["norm"])
    up = jnp.einsum("btd,dc->btc", y, p["w_up"].astype(y.dtype))
    up = shd(up, "batch", "seq", "act_heads")
    xi, z = up[..., :di], up[..., di:]
    if state is None:
        conv_state = None
        C = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n = jnp.zeros((b, nh, dh), jnp.float32)
    else:
        C, n, conv_state = state
    xc, new_conv = _causal_conv(xi, p["conv"], conv_state)
    xc = jax.nn.silu(xc)
    q = jnp.einsum("btc,ce->bte", xc, p["wq"].astype(xc.dtype)).reshape(b, t, nh, dh)
    k = jnp.einsum("btc,ce->bte", xc, p["wk"].astype(xc.dtype)).reshape(b, t, nh, dh)
    v = jnp.einsum("btc,ce->bte", xi, p["wv"].astype(xi.dtype)).reshape(b, t, nh, dh)
    logi, logf = _mlstm_gates(xc, p, nh)
    if t == 1 and state is not None:
        h, (C, n) = mlstm_step(q, k, v, logi, logf, (C, n))
    else:
        h, (C, n) = mlstm_chunkwise(q, k, v, logi, logf, (C, n),
                                    chunk=min(chunk, t))
    h = h.reshape(b, t, di).astype(x.dtype)
    h = common.rms_norm(h, p["out_norm"])
    h = h * jax.nn.silu(z)                                # output gate
    out = jnp.einsum("btc,cd->btd", h, p["w_down"].astype(h.dtype))
    out = shd(out, "batch", "seq", "act_embed")
    new_state = None if state is None else (C, n, new_conv)
    return x + out, new_state


# ---------------------------------------------------------------------------
# sLSTM


def slstm_init(pb: ParamBuilder, cfg: ModelConfig):
    d, nh = cfg.d_model, cfg.num_heads
    dh = d // nh
    pb.dense("norm", (d,), ("norm",), zero=True)
    pb.dense("w_in", (d, 4 * d), ("embed", "ssm_inner"), fan_in=d)
    pb.dense("r", (4, nh, dh, dh), (None, "heads", None, None), fan_in=dh)
    pb.dense("b", (4 * d,), (None,), zero=True)
    pb.dense("out_norm", (d,), ("norm",), zero=True)
    ff = _ffn_width(d)
    fb = pb.child("ffn")
    common.mlp_init(fb, d, ff)


def slstm_block(x, p, cfg: ModelConfig, shd: Sharder, state):
    """Time-recurrent sLSTM with exponential gating + stabilizer.

    state: (c, n, m, h) each [B, NH, dh] or None (zeros).
    """
    b, t, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    y = common.rms_norm(x, p["norm"])
    wx = jnp.einsum("btd,de->bte", y, p["w_in"].astype(y.dtype))
    wx = (wx + p["b"].astype(wx.dtype)).astype(jnp.float32)
    wx = wx.reshape(b, t, 4, nh, dh)
    r = p["r"].astype(jnp.float32)

    if state is None:
        zeros = jnp.zeros((b, nh, dh), jnp.float32)
        state = (zeros, zeros, zeros - 1e30, zeros)
        # m initialized very negative => first-step gates reduce correctly
        state = (zeros, zeros, jnp.full((b, nh, dh), -1e30), zeros)

    def step(carry, wx_t):
        c, n, m, h = carry
        rec = jnp.einsum("bhd,ghde->bghe", h, r)          # [B,4,NH,dh]
        pre = wx_t + rec
        zi, ii, fi, oi = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        zi = jnp.tanh(zi)
        oi = jax.nn.sigmoid(oi)
        logi = jnp.minimum(ii, I_CLAMP)
        logf = jax.nn.log_sigmoid(fi)
        m_new = jnp.maximum(logf + m, logi)
        i_ = jnp.exp(logi - m_new)
        f_ = jnp.exp(logf + m - m_new)
        c = f_ * c + i_ * zi
        n = f_ * n + i_
        h_new = oi * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, m_new, h_new), h_new

    state, hs = lax.scan(step, state, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(b, t, d).astype(x.dtype)
    h = common.rms_norm(h, p["out_norm"])
    x = x + h
    x = x + common.mlp(common.rms_norm(x, p["norm"]), p["ffn"], shd)
    return x, state


# ---------------------------------------------------------------------------
# full model


class XLSTM:
    def __init__(self, cfg: ModelConfig, mesh=None, *, chunk=256, remat=True,
                 attn_impl=None, q_block=None,   # attn-free: accepted, unused
                 shd_rules=None, barrier=False):
        self.cfg = cfg
        self.shd = Sharder(mesh, rules=shd_rules, barrier=barrier)
        self.chunk = chunk
        self.remat = remat
        every = cfg.slstm_every or (cfg.num_layers + 1)
        self.slstm_idx = [i for i in range(cfg.num_layers)
                          if (i + 1) % every == 0]
        # groups of consecutive mLSTM layers between sLSTM layers
        self.groups = []
        start = 0
        for si in self.slstm_idx + [cfg.num_layers]:
            self.groups.append(si - start)  # mlstm count before this slstm
            start = si + 1
        self.n_mlstm = cfg.num_layers - len(self.slstm_idx)

    def init(self, key):
        cfg = self.cfg
        pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
        common.embed_init(pb, cfg)
        mb = pb.child("mlstm")
        mlstm_init(mb, cfg, self.n_mlstm)
        for i in range(len(self.slstm_idx)):
            sb = pb.child(f"slstm_{i}")
            slstm_init(sb, cfg)
        return pb.build()

    def _stack(self, x, params, states):
        """states: dict or None. Returns (x, new_states)."""
        cfg, shd = self.cfg, self.shd
        new_states = {} if states is not None else None
        m_off = 0

        def mbody(carry, inp):
            xc = carry
            if states is None:
                p = inp
                st = None
            else:
                p, st = inp
            xc, nst = mlstm_block(xc, p, cfg, shd, st, chunk=self.chunk)
            return xc, nst

        if self.remat:
            mbody = jax.checkpoint(
                mbody, policy=jax.checkpoint_policies.nothing_saveable)

        for gi, g_count in enumerate(self.groups):
            if g_count:
                sl = lambda a: jax.tree.map(
                    lambda v: lax.dynamic_slice_in_dim(v, m_off, g_count, 0),
                    a)
                gp = sl(params["mlstm"])
                if states is None:
                    x, _ = lax.scan(mbody, x, gp)
                else:
                    gst = jax.tree.map(
                        lambda v: lax.dynamic_slice_in_dim(v, m_off, g_count, 0),
                        states["mlstm"])
                    x, nst = lax.scan(mbody, x, (gp, gst))
                    new_states.setdefault("_m", []).append(nst)
                m_off += g_count
            # pin the residual sharding at group boundaries: without this
            # GSPMD flips the carried-state sharding between group scans
            # (involuntary full rematerialization warnings)
            x = shd(x, "batch", "seq", "act_embed")
            if gi < len(self.slstm_idx):
                p = params[f"slstm_{gi}"]
                st = None if states is None else states[f"slstm_{gi}"]
                x, nst = slstm_block(x, p, cfg, shd, st)
                if states is not None:
                    new_states[f"slstm_{gi}"] = nst
        if states is not None:
            parts = new_states.pop("_m")
            new_states["mlstm"] = jax.tree.map(
                lambda *vs: jnp.concatenate(vs, axis=0), *parts)
        return x, new_states

    def forward(self, params, batch):
        dtype = jnp.dtype(self.cfg.dtype)
        x = common.embed(batch["tokens"], params, dtype)
        x = self.shd(x, "batch", "seq", "act_embed")
        x, _ = self._stack(x, params, None)
        return common.unembed(x, params, self.shd), 0.0

    # -- serving: state = recurrent state (O(1) in sequence length) ---------

    def init_cache(self, batch_size, max_seq, dtype=jnp.bfloat16):
        cfg = self.cfg
        d, nh = cfg.d_model, cfg.num_heads
        di = 2 * d
        dh = di // nh
        lm = self.n_mlstm
        st = {
            "mlstm": (
                jnp.zeros((lm, batch_size, nh, dh, dh), jnp.float32),
                jnp.zeros((lm, batch_size, nh, dh), jnp.float32),
                jnp.zeros((lm, batch_size, 3, di), jnp.float32),
            )
        }
        sdh = d // nh
        for i in range(len(self.slstm_idx)):
            zeros = jnp.zeros((batch_size, nh, sdh), jnp.float32)
            st[f"slstm_{i}"] = (zeros, zeros, jnp.full_like(zeros, -1e30), zeros)
        return st

    def cache_axes(self):
        st = {
            "mlstm": (
                ("layers", "batch", "act_heads", None, None),
                ("layers", "batch", "act_heads", None),
                ("layers", "batch", None, "ssm_inner"),
            )
        }
        for i in range(len(self.slstm_idx)):
            ax = ("batch", "act_heads", None)
            st[f"slstm_{i}"] = (ax, ax, ax, ax)
        return st

    def prefill(self, params, batch, states, start_pos=None):
        """Prefill a chunk; carried state in ``states`` resumes across
        chunks (mLSTM/sLSTM are position-free, so ``start_pos`` is
        accepted for the uniform chunked-prefill signature and ignored)."""
        del start_pos  # recurrent: position-free
        dtype = jnp.dtype(self.cfg.dtype)
        x = common.embed(batch["tokens"], params, dtype)
        x = self.shd(x, "batch", "seq", "act_embed")
        x, states = self._stack(x, params, states)
        return common.unembed(x[:, -1:], params, self.shd), states

    def decode_step(self, params, token, pos, states):
        del pos  # recurrent: position-free
        dtype = jnp.dtype(self.cfg.dtype)
        x = common.embed(token, params, dtype)
        x = self.shd(x, "batch", "seq", "act_embed")
        x, states = self._stack(x, params, states)
        return common.unembed(x, params, self.shd), states
