"""Request-lifecycle and engine-step spans.

A :class:`Tracer` records nested, monotonic-clock spans into a thread-safe
ring buffer. Two usage shapes:

    with tracer.span("decode_step", queue_depth=3) as sp:
        ...                          # lexical: one engine step
        sp.set("window", idx)

    h = tracer.begin("queued", track="req7")    # non-lexical: a request's
    ...                                          # life crosses many steps
    h.end(finish_reason="eos")

Lexical spans MUST use the ``with`` form and non-lexical handles MUST be
ended on every path — dalek-lint DLK007 (``unclosed-span``) enforces both
statically.

Spans are cheap on purpose: beginning/ending a span is a clock read plus a
few attribute writes under a lock that is only contended when engines share
a tracer across threads. The serving bench gates the overhead (<5% decode
tokens/s with spans on vs off).

Attribute conventions the exporter understands:

``window``   index of the ``MonitorSession`` sample window this span's
             compute was measured in (see ``obs.events``). The exporter
             assigns that window's joules to the span — every window is
             referenced by exactly one span, so per-span energy sums to the
             session report total exactly.
``track``    timeline row: "engine" (default) for step spans, "req<N>" for
             request-lifecycle spans.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

__all__ = ["Span", "SpanRecord", "Tracer"]


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span (immutable; what ``Tracer.spans()`` returns)."""

    span_id: int
    parent_id: Optional[int]
    name: str
    track: str
    t0: float                       # seconds since tracer epoch
    t1: float
    attrs: Dict[str, object]

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class Span:
    """A live span. Use as a context manager (lexical) or keep the handle
    and call :meth:`end` exactly once (non-lexical)."""

    __slots__ = ("_tracer", "span_id", "parent_id", "name", "track",
                 "t0", "_attrs", "_ended")

    def __init__(self, tracer: "Tracer", span_id: int,
                 parent_id: Optional[int], name: str, track: str,
                 t0: float, attrs: Dict[str, object]):
        self._tracer = tracer
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.track = track
        self.t0 = t0
        self._attrs = attrs
        self._ended = False

    def set(self, key: str, value) -> "Span":
        """Attach/overwrite one attribute (chainable)."""
        self._attrs[key] = value
        return self

    def update(self, **attrs) -> "Span":
        self._attrs.update(attrs)
        return self

    def end(self, **attrs):
        """Finish the span; extra ``attrs`` merge in. Idempotent so an
        exception path and a normal path may both reach it."""
        if self._ended:
            return
        self._ended = True
        self._attrs.update(attrs)
        self._tracer._finish(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._attrs.setdefault("error", exc_type.__name__)
        self.end()
        return False


class _NullSpan:
    """No-op span so call sites need no ``if tracer`` guards on ``set``."""

    __slots__ = ()

    def set(self, key, value):
        return self

    def update(self, **attrs):
        return self

    def end(self, **attrs):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe span recorder over a bounded ring buffer.

    The clock is ``time.perf_counter`` rebased to the tracer's creation
    (monotonic, never wall time). Nesting is tracked per thread: a span
    begun while another is open on the same thread records it as parent.
    When the ring fills, the *oldest* finished spans are dropped and
    ``n_dropped`` counts them — a long-running engine keeps the most recent
    window of history instead of growing without bound.
    """

    def __init__(self, capacity: int = 65536, clock=time.perf_counter):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        self._done: List[SpanRecord] = []
        self._next_id = 0
        self._n_dropped = 0
        self._n_started = 0
        self._stacks = threading.local()

    # -- clock ---------------------------------------------------------------

    def now(self) -> float:
        """Seconds since the tracer epoch."""
        return self._clock() - self._epoch

    # -- span creation -------------------------------------------------------

    def _stack(self) -> List[int]:
        st = getattr(self._stacks, "ids", None)
        if st is None:
            st = self._stacks.ids = []
        return st

    def span(self, name: str, track: str = "engine", **attrs) -> Span:
        """Open a lexical span — always use as ``with tracer.span(...)``
        (DLK007 flags any other shape)."""
        return self._begin(name, track, attrs, push=True)

    def begin(self, name: str, track: str = "engine", **attrs) -> Span:
        """Open a non-lexical span handle; the caller owns ending it.
        Does not join the thread's nesting stack — a request's lifecycle
        span is not the parent of unrelated engine steps that happen to
        run while it is queued."""
        return self._begin(name, track, attrs, push=False)

    def _begin(self, name, track, attrs, push: bool) -> Span:
        stack = self._stack()
        parent = stack[-1] if (push and stack) else None
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            self._n_started += 1
        sp = Span(self, sid, parent, name, track, self.now(), dict(attrs))
        if push:
            stack.append(sid)
        return sp

    def instant(self, name: str, track: str = "engine", **attrs):
        """Zero-duration marker (e.g. a request's ``finish`` event)."""
        t = self.now()
        self._record(SpanRecord(span_id=self._take_id(), parent_id=None,
                                name=name, track=track, t0=t, t1=t,
                                attrs=dict(attrs)))

    def _take_id(self) -> int:
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            self._n_started += 1
            return sid

    # -- completion ----------------------------------------------------------

    def _finish(self, span: Span):
        stack = self._stack()
        if stack and stack[-1] == span.span_id:
            stack.pop()
        self._record(SpanRecord(
            span_id=span.span_id, parent_id=span.parent_id, name=span.name,
            track=span.track, t0=span.t0, t1=self.now(),
            attrs=span._attrs))

    def _record(self, rec: SpanRecord):
        with self._lock:
            self._done.append(rec)
            if len(self._done) > self.capacity:
                drop = len(self._done) - self.capacity
                del self._done[:drop]
                self._n_dropped += drop

    # -- inspection ----------------------------------------------------------

    def spans(self) -> List[SpanRecord]:
        """Finished spans, oldest first (start-time order)."""
        with self._lock:
            out = list(self._done)
        out.sort(key=lambda r: (r.t0, r.span_id))
        return out

    @property
    def n_dropped(self) -> int:
        with self._lock:
            return self._n_dropped

    @property
    def n_started(self) -> int:
        with self._lock:
            return self._n_started

    def __len__(self) -> int:
        with self._lock:
            return len(self._done)

    def clear(self):
        """Drop recorded spans (benchmark warmup); ids and clock keep
        going so already-open handles still end cleanly."""
        with self._lock:
            self._done = []
            self._n_dropped = 0
            self._n_started = 0


def span_tree(records: List[SpanRecord]) -> Dict[Optional[int], List[SpanRecord]]:
    """parent_id -> children (start-time order); roots under ``None``."""
    out: Dict[Optional[int], List[SpanRecord]] = {}
    for r in sorted(records, key=lambda r: (r.t0, r.span_id)):
        out.setdefault(r.parent_id, []).append(r)
    return out
