"""Serving steps: prefill (builds KV caches / recurrent state) and decode
(one new token against a cache of ``seq_len``). Cache sharding comes from the
model's ``cache_axes()`` logical axes; for batch=1 long-context decode the
``kv_seq`` rule is overridden to sequence-shard the cache (context/SP)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import spec_for


def make_prefill_step(model):
    def prefill_step(params, batch, caches):
        logits, caches = model.prefill(params, batch, caches)
        return logits, caches
    return prefill_step


def make_decode_step(model, greedy=True):
    def decode_step(params, tokens, pos, caches):
        logits, caches = model.decode_step(params, tokens, pos, caches)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, caches
    return decode_step


def serve_rules(shape):
    """Sharding-rule overrides per shape cell.

    batch=1 (long_500k): nothing to shard on batch -> sequence-shard KV
    caches over ("pod","data") and keep TP on heads.
    """
    if shape.global_batch == 1:
        return {"batch": None, "kv_seq": ("pod", "data")}
    return {}


def cache_specs(mesh, model, cache_sds, rules=None):
    axes = model.cache_axes()
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda a, c: spec_for(mesh, a, c.shape, rules),
        axes, cache_sds, is_leaf=is_axes)


def abstract_cache(model, batch_size, max_seq, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model.init_cache(batch_size, max_seq, dtype))
