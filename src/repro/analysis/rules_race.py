"""DLK012 unguarded-shared-state.

Groundwork for the async intake thread (ROADMAP): once a second thread
feeds the engine, every class that already owns a ``threading.Lock`` is a
shared object — and a field that is written under ``with self._lock`` in
one method but read bare in another is a race waiting for that thread to
land (torn reads of dict iteration, lost increments).

The rule is class-local with project-wide call-site reasoning:

* a class is *lock-guarded* if it assigns ``threading.Lock()``/``RLock()``
  to ``self.<attr>`` **or** uses ``with self.<attr>`` where the attribute
  name contains "lock" (the lock may be created in a base class);
* an access ``self.<field>`` is *guarded* if an enclosing ``with
  self.<lock>`` covers it, or the enclosing method is itself
  guaranteed-guarded: its name ends in ``_locked``, or every call site
  ``<recv>.<meth>(...)`` in non-test modules sits under ``with
  <recv>.<lock>`` (or inside another guaranteed-guarded method) — computed
  to a fixpoint through :class:`~repro.analysis.project.ProjectIndex`'s
  call-site table;
* a field is flagged when it has a write outside ``__init__``, at least
  one guarded access, and at least one bare access outside ``__init__`` —
  mixed discipline, the torn-read shape.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

from repro.analysis.core import (Finding, ModuleContext, Rule, qualname,
                                 register)

_LOCK_CTORS = {"Lock", "RLock"}


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    """Lock attributes this class owns or uses (``self.<attr>``)."""
    out: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            qn = qualname(node.value.func)
            if qn.rsplit(".", 1)[-1] in _LOCK_CTORS:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) \
                            and isinstance(tgt.value, ast.Name) \
                            and tgt.value.id == "self":
                        out.add(tgt.attr)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                qn = qualname(item.context_expr)
                if qn.startswith("self.") and qn.count(".") == 1 \
                        and "lock" in qn.lower():
                    out.add(qn.split(".", 1)[1])
    return out


def _methods(cls: ast.ClassDef) -> List[ast.FunctionDef]:
    return [n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


#: container methods that mutate the receiver in place — writing through
#: them races with bare reads just like rebinding the field does
_MUTATORS = {"append", "appendleft", "extend", "insert", "pop", "popleft",
             "popitem", "remove", "clear", "update", "setdefault", "add",
             "discard", "sort"}


def _is_write(ctx: ModuleContext, node: ast.Attribute) -> bool:
    """Store/Del of ``self.<field>``, an item store through it
    (``self._x[k] = v``), or an in-place mutator call (``self._x.append``)."""
    if isinstance(node.ctx, (ast.Store, ast.Del)):
        return True
    parent = ctx.parent(node)
    if isinstance(parent, ast.Subscript) and parent.value is node \
            and isinstance(parent.ctx, (ast.Store, ast.Del)):
        return True
    if isinstance(parent, ast.Attribute) and parent.value is node \
            and parent.attr in _MUTATORS:
        gp = ctx.parent(parent)
        if isinstance(gp, ast.Call) and gp.func is parent:
            return True
    return False


def _under_lock(ctx: ModuleContext, node, locks: Set[str],
                recv: str = "self") -> bool:
    """Is ``node`` inside ``with <recv>.<lock>`` for one of ``locks``?"""
    wanted = {f"{recv}.{la}" for la in locks}
    for anc in ctx.ancestors(node):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                if qualname(item.context_expr) in wanted:
                    return True
    return False


@register
class UnguardedSharedState(Rule):
    """Field accessed both under ``with self._lock`` and bare."""

    code = "DLK012"
    name = "unguarded-shared-state"
    skip_tests = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for cls in ctx.tree.body:
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = _lock_attrs(cls)
            if not locks:
                continue
            yield from self._check_class(ctx, cls, locks)

    def _check_class(self, ctx, cls, locks) -> Iterator[Finding]:
        methods = _methods(cls)
        method_names = {m.name for m in methods}
        guarded_methods = self._guarded_methods(ctx, cls, methods, locks)

        # (field) -> [(node, method, guarded, is_write)]
        accesses: Dict[str, List[Tuple[ast.Attribute, str, bool, bool]]] = {}
        for meth in methods:
            for node in ast.walk(meth):
                if not (isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"):
                    continue
                field = node.attr
                if field in locks or field in method_names:
                    continue
                guarded = (_under_lock(ctx, node, locks)
                           or meth.name in guarded_methods)
                is_write = _is_write(ctx, node)
                accesses.setdefault(field, []).append(
                    (node, meth.name, guarded, is_write))

        for field in sorted(accesses):
            uses = accesses[field]
            written = any(w and m != "__init__" for _, m, _, w in uses)
            any_guarded = any(g for _, m, g, _ in uses if m != "__init__")
            if not (written and any_guarded):
                continue
            for node, meth_name, guarded, is_write in uses:
                if guarded or meth_name == "__init__":
                    continue
                verb = "written" if is_write else "read"
                yield ctx.finding(
                    self, node,
                    f"'self.{field}' is {verb} without the lock in "
                    f"'{cls.name}.{meth_name}' but accessed under "
                    f"'with self.{sorted(locks)[0]}' elsewhere — torn "
                    "read/lost update once a second thread touches this "
                    "object")

    @staticmethod
    def _guarded_methods(ctx, cls, methods, locks) -> Set[str]:
        """Methods that only ever run with the lock held: named
        ``*_locked``, or every project call site is under the lock (or in
        another guaranteed-guarded method) — a fixpoint over call sites."""
        proj = ctx.project
        guarded = {m.name for m in methods if m.name.endswith("_locked")}
        candidates = [m.name for m in methods
                      if not m.name.startswith("__")
                      and m.name not in guarded]
        changed = True
        while changed:
            changed = False
            for name in candidates:
                if name in guarded:
                    continue
                sites = proj.attr_calls.get(name, []) if proj is not None \
                    else []
                if not sites:
                    continue
                ok = True
                for sctx, call in sites:
                    recv = qualname(call.func.value)
                    if not recv:
                        ok = False
                        break
                    if _under_lock(sctx, call, locks, recv=recv):
                        continue
                    # a self-call from a method already known to hold
                    # the lock (same class only)
                    if recv == "self" and sctx is ctx:
                        encl = sctx.enclosing_function(call)
                        if encl is not None \
                                and sctx.enclosing_class(call) is cls \
                                and encl.name in guarded:
                            continue
                    ok = False
                    break
                if ok:
                    guarded.add(name)
                    changed = True
        return guarded
