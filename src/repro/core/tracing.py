"""Compile accounting: ``TraceStats`` + ``counting_jit``.

Bounded compile counts are a serving invariant (PR 4): every jitted
executable the repo runs must be visible to a ``TraceStats`` so the CI
cross-run gate can fail any change that reintroduces a retrace. This
module is the single place ``jax.jit`` is allowed to appear — everything
else goes through :func:`counting_jit`, and the ``repro.analysis`` static
analyzer (rule DLK001 *bare-jit*) enforces exactly that.

Lives in ``repro.core`` (not ``repro.serve``) because the training and
launch layers meter their compiles too; ``repro.serve.step`` re-exports
both names for compatibility.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax


class TraceStats:
    """Per-step-family jit trace/compile counters.

    One counter per step name ("prefill", "decode", ...): ``counting_jit``
    bumps it whenever a call presents an abstract input signature (pytree
    structure + leaf shapes/dtypes + static values) the wrapper has not seen
    before — exactly the condition under which ``jax.jit`` traces and XLA
    compiles a new executable. Bounded compile counts are a serving
    invariant: with length bucketing, ``compiles("prefill")`` can never
    exceed the bucket count no matter the traffic shape, and the CI
    regression gate fails any PR that reintroduces a retrace.
    """

    def __init__(self):
        self.compile_counts: Dict[str, int] = {}
        self.call_counts: Dict[str, int] = {}

    def record(self, name: str, new_trace: bool):
        self.call_counts[name] = self.call_counts.get(name, 0) + 1
        if new_trace:
            self.compile_counts[name] = self.compile_counts.get(name, 0) + 1

    def compiles(self, name: Optional[str] = None) -> int:
        if name is not None:
            return self.compile_counts.get(name, 0)
        return sum(self.compile_counts.values())

    def calls(self, name: str) -> int:
        return self.call_counts.get(name, 0)

    def snapshot(self) -> Dict[str, int]:
        return dict(self.compile_counts)


def _abstract_signature(args, kwargs):
    """Hashable abstract signature of a call: treedef + per-leaf
    (shape, dtype) for arrays, value identity for python statics."""
    leaves, treedef = jax.tree.flatten((args, kwargs))

    def describe(leaf):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            return (tuple(leaf.shape), str(leaf.dtype),
                    bool(getattr(leaf, "weak_type", False)))
        return ("py", type(leaf).__name__, repr(leaf))

    return (treedef,) + tuple(describe(l) for l in leaves)


def counting_jit(fn, name: str, stats: Optional[TraceStats] = None,
                 on_compile=None, **jit_kwargs):
    """``jax.jit(fn)`` wrapped with trace accounting.

    A call that grows the jit executable cache counts as one compile on
    ``stats`` (and fires ``on_compile(name)`` — the hook engines use to
    surface compile activity through telemetry counters). The primary
    detector is the cache-size delta around the call (exact and O(1)); when
    that private accessor is unavailable the wrapper falls back to tracking
    abstract input signatures, which costs a pytree flatten per call. The
    wrapped jitted function is exposed as ``wrapper.jitted``; AOT users
    call ``wrapper.lower(...)`` — a lower is a trace, so it records one
    compile on ``stats`` (the dryrun driver's explicit-compile path).
    """
    jitted = jax.jit(fn, **jit_kwargs)  # dalek: allow[bare-jit] counting_jit IS the tracked wrapper
    cache_size = getattr(jitted, "_cache_size", None)
    seen = set()

    def wrapper(*args, **kwargs):
        if cache_size is not None:
            before = cache_size()
            out = jitted(*args, **kwargs)
            new = cache_size() > before
        else:
            sig = _abstract_signature(args, kwargs)
            new = sig not in seen
            if new:
                seen.add(sig)
            out = jitted(*args, **kwargs)
        if stats is not None:
            stats.record(name, new)
        if new and on_compile is not None:
            on_compile(name)
        return out

    def lower(*args, **kwargs):
        if stats is not None:
            stats.record(name, True)
        if on_compile is not None:
            on_compile(name)
        return jitted.lower(*args, **kwargs)

    wrapper.jitted = jitted
    wrapper.lower = lower
    wrapper.step_name = name
    wrapper.stats = stats
    return wrapper
