"""Paper Fig. 5 / Fig. 7 (Sec. 5.2/5.4): peak compute across DPA variants.

FMA f32, DPA2 (bf16->f32) and DPA4 (i8->i32) matmuls — measured wall-clock
Gop/s on this host via XLA, plus the TPU v5e model peaks the kernels target
(the paper's observed 2x ladder FMA->DPA2->DPA4 maps to the MXU's
f32:bf16:int8 throughput ladder).
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.tracing import TraceStats, counting_jit
from repro.kernels.dpa_matmul import ref as dpa_ref

M = K = N = 512
V5E_PEAKS = {"fma_f32": 49e12, "dpa2": 197e12, "dpa4": 394e12}


def run():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    flops = 2 * M * K * N
    stats = TraceStats()
    for variant in ("fma_f32", "dpa2", "dpa4"):
        fn = counting_jit(lambda x, y, v=variant: dpa_ref.matmul(x, y, v),
                          f"peak/{variant}", stats)
        t = time_fn(fn, a, b)
        gops = flops / t / 1e9
        emit(f"peak/{variant}/{M}x{K}x{N}", t,
             f"{gops:.1f}Gop/s;v5e_target={V5E_PEAKS[variant]/1e12:.0f}Top/s")


if __name__ == "__main__":
    run()
