"""Length-bucketed prefill: bucketed (right-padded) slot prefill must match
exact-length prefill bit-for-bit — next-token logits, sampled token, AND the
scattered cache state — across bucket edges and model families (dense +
gemma3 local:global window rings), and a bucketed prefill followed by decode
must reproduce the unbucketed trajectory. Compile activity is the other half
of the contract: serving a workload of many distinct prompt lengths may
compile at most ``len(buckets)`` prefill executables (the ``TraceStats``
gate CI regresses on). Satellite regressions ride along: ``RequestQueue.shed``
drops the request from the deque, ``queued_tokens`` counts prompt + budget,
and static-engine filler rows stay out of throughput/energy attribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import configs
from repro.models import build_model
from repro.models.registry import serving_caps
from repro.serve.engine import (ContinuousEngine, Request, ServeEngine,
                                resolve_buckets)
from repro.serve.queue import RequestQueue
from repro.serve.step import (TraceStats, bucket_for, counting_jit,
                              make_decode_step, make_slot_prefill,
                              pad_to_bucket, prefill_buckets)

MAX_SEQ = 48


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_smoke("granite-20b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def dense_steps(dense):
    _, model, _ = dense
    return (jax.jit(make_slot_prefill(model)),
            jax.jit(make_slot_prefill(model, bucketed=True)))


@pytest.fixture(scope="module")
def windowed():
    cfg = configs.get_smoke("gemma3-27b")
    model = build_model(cfg, q_block=8)
    params, _ = model.init(jax.random.key(1))
    return cfg, model, params


def _check_bucketed_matches_exact(cfg, model, params, exact, bucketed,
                                  buckets, n, seed=0, max_seq=MAX_SEQ):
    """Exact-length vs bucketed slot prefill of the same prompt into slot 1
    of a batch-2 cache: logits, next token, and full cache state bit-equal."""
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    ca = model.init_cache(2, max_seq)
    cb = model.init_cache(2, max_seq)
    ta, la, ca = exact(params, jnp.asarray(prompt[None]), jnp.int32(1), ca)
    padded, true_len = pad_to_bucket(prompt, buckets)
    assert true_len == n and len(padded) == bucket_for(n, buckets)
    tb, lb, cb = bucketed(params, jnp.asarray(padded[None]),
                          jnp.int32(true_len), jnp.int32(1), cb)
    assert np.array_equal(np.asarray(la), np.asarray(lb)), \
        f"len={n}: bucketed logits differ from exact-length prefill"
    assert int(np.asarray(ta)[0, 0]) == int(np.asarray(tb)[0, 0])
    for xa, xb in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
        assert np.array_equal(np.asarray(xa), np.asarray(xb)), \
            f"len={n}: bucketed cache state differs (stale pad KV leaked)"
    return prompt, tb, cb


# ---------------------------------------------------------------------------
# bucket arithmetic


def test_bucket_edges():
    assert prefill_buckets(48) == (8, 16, 32, 48)
    assert prefill_buckets(64) == (8, 16, 32, 64)
    assert prefill_buckets(8) == (8,)
    assert prefill_buckets(5) == (5,)
    assert bucket_for(9, (8, 16, 32)) == 16
    assert bucket_for(16, (8, 16, 32)) == 16
    assert bucket_for(99, (8, 16, 32)) == 99   # beyond edges: exact


def test_pad_to_bucket_right_pads():
    padded, n = pad_to_bucket(np.arange(1, 6, dtype=np.int32), (8, 16))
    assert n == 5 and len(padded) == 8
    assert list(padded) == [1, 2, 3, 4, 5, 0, 0, 0]
    exact, n = pad_to_bucket(np.arange(8, dtype=np.int32), (8, 16))
    assert n == 8 and len(exact) == 8           # on the edge: no padding


def test_resolve_buckets():
    assert resolve_buckets("off", 48) is None
    assert resolve_buckets(None, 48) is None
    assert resolve_buckets("auto", 48) == (8, 16, 32, 48)
    # explicit edges are deduped/sorted and extended to cover max_seq
    assert resolve_buckets([16, 8, 8], 48) == (8, 16, 48)
    with pytest.raises(ValueError):
        resolve_buckets([], 48)


def test_auto_bucketing_degrades_for_recurrent_models():
    """Right-pad bucketing would corrupt carried state, so 'auto' falls
    back to exact-length prefill instead of crashing at serve time —
    and explicitly requested buckets are a loud error. The families
    *declare* this (``serving_caps``); no model-method sniffing."""
    cfg = configs.get_smoke("xlstm-1.3b")
    assert not serving_caps(cfg).bucketed_prefill
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_size=1, max_seq=8,
                      telemetry=False)
    assert eng.buckets is None
    eng = ContinuousEngine(model, params, batch_size=1, max_seq=8,
                           telemetry=False)
    assert eng.buckets is None
    with pytest.raises(ValueError, match="bucketed_prefill"):
        ServeEngine(model, params, batch_size=1, max_seq=8, telemetry=False,
                    prefill_buckets=[4, 8])


def test_counting_jit_counts_signatures():
    stats = TraceStats()
    f = counting_jit(lambda x: x * 2, "f", stats)
    f(jnp.ones((2,)))
    f(jnp.zeros((2,)))                  # same shape: no new trace
    f(jnp.ones((3,)))                   # new shape: compile
    assert stats.compiles("f") == 2 and stats.calls("f") == 3


# ---------------------------------------------------------------------------
# bit-for-bit equivalence across bucket edges


def test_bucketed_prefill_matches_exact_at_bucket_edges(dense, dense_steps):
    """len = edge-1, edge, edge+1 for every bucket edge."""
    cfg, model, params = dense
    exact, bucketed = dense_steps
    buckets = prefill_buckets(MAX_SEQ)
    lengths = sorted({min(max(n, 1), MAX_SEQ)
                      for e in buckets for n in (e - 1, e, e + 1)})
    for n in lengths:
        _check_bucketed_matches_exact(cfg, model, params, exact, bucketed,
                                      buckets, n, seed=n)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(1, MAX_SEQ), seed=st.integers(0, 2**31 - 1))
    def test_bucketed_prefill_matches_exact_property(dense, dense_steps,
                                                     n, seed):
        cfg, model, params = dense
        exact, bucketed = dense_steps
        _check_bucketed_matches_exact(cfg, model, params, exact, bucketed,
                                      prefill_buckets(MAX_SEQ), n, seed=seed)


def test_bucketed_prefill_matches_exact_seeded(dense, dense_steps):
    """Seeded sweep of the same property (runs without hypothesis)."""
    cfg, model, params = dense
    exact, bucketed = dense_steps
    rng = np.random.default_rng(42)
    for n in rng.integers(1, MAX_SEQ + 1, 6):
        _check_bucketed_matches_exact(cfg, model, params, exact, bucketed,
                                      prefill_buckets(MAX_SEQ), int(n),
                                      seed=int(n) + 1000)


def test_windowed_bucketed_prefill_matches_exact(windowed):
    """gemma3 local:global ring caches: the ring must be built from the
    true last token, not the pad tail."""
    cfg, model, params = windowed
    exact = jax.jit(make_slot_prefill(model))
    bucketed = jax.jit(make_slot_prefill(model, bucketed=True))
    buckets = prefill_buckets(32)
    for n in (7, 9, 16, 31):
        _check_bucketed_matches_exact(cfg, model, params, exact, bucketed,
                                      buckets, n, seed=n, max_seq=32)


def test_bucketed_prefill_then_decode_matches_exact_trajectory(dense,
                                                               dense_steps):
    """A bucketed prefill followed by N decode steps reproduces the
    unbucketed trajectory: per-step logits and tokens, bit-for-bit."""
    cfg, model, params = dense
    exact, bucketed = dense_steps
    n = 13                                       # interior of the 16 bucket
    prompt, tok_b, cache_b = _check_bucketed_matches_exact(
        cfg, model, params, exact, bucketed, prefill_buckets(MAX_SEQ), n,
        seed=7)
    ca = model.init_cache(2, MAX_SEQ)
    tok_a, _, ca = exact(params, jnp.asarray(prompt[None]), jnp.int32(1), ca)
    decode = jax.jit(make_decode_step(model))
    cb = cache_b
    for step in range(6):
        pos = jnp.asarray([0, n + step], jnp.int32)
        ta = jnp.asarray([[0], [int(np.asarray(tok_a)[0, 0])]], jnp.int32)
        tb = jnp.asarray([[0], [int(np.asarray(tok_b)[0, 0])]], jnp.int32)
        tok_a, la, ca = decode(params, ta, pos, ca)
        tok_b, lb, cb = decode(params, tb, pos, cb)
        assert np.array_equal(np.asarray(la)[1], np.asarray(lb)[1]), \
            f"decode step {step}: logits diverged after bucketed prefill"
        assert int(np.asarray(tok_a)[1, 0]) == int(np.asarray(tok_b)[1, 0])


# ---------------------------------------------------------------------------
# engine-level equivalence + the bounded-compile contract


def _mixed_reqs(cfg, lengths, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lengths)]


def test_continuous_engine_bucketed_matches_exact(dense):
    cfg, model, params = dense
    lengths = [3, 9, 17, 33, 47]
    a = _mixed_reqs(cfg, lengths, seed=5)
    b = _mixed_reqs(cfg, lengths, seed=5)
    e_off = ContinuousEngine(model, params, batch_size=3, max_seq=64,
                             telemetry=False, prefill_buckets="off")
    e_on = ContinuousEngine(model, params, batch_size=3, max_seq=64,
                            telemetry=False, prefill_buckets="auto")
    s_off = e_off.serve(a)
    s_on = e_on.serve(b)
    for ra, rb in zip(a, b):
        assert ra.output == rb.output
    assert s_off["prefill_compiles"] == len(set(lengths))   # the explosion
    assert s_on["prefill_compiles"] <= len(e_on.buckets)    # the fix


def test_windowed_engine_bucketed_matches_exact(windowed):
    cfg, model, params = windowed
    lengths = [5, 11, 21]
    a = _mixed_reqs(cfg, lengths, max_new=4, seed=6)
    b = _mixed_reqs(cfg, lengths, max_new=4, seed=6)
    ContinuousEngine(model, params, batch_size=2, max_seq=32,
                     telemetry=False, prefill_buckets="off").serve(a)
    ContinuousEngine(model, params, batch_size=2, max_seq=32,
                     telemetry=False, prefill_buckets="auto").serve(b)
    for ra, rb in zip(a, b):
        assert ra.output == rb.output


def test_static_engine_bucketed_matches_exact(dense):
    """Static batch: left-pad to the batch max, right-pad to the bucket
    edge; logits come from the true last position."""
    cfg, model, params = dense
    lengths = [3, 11]                            # batch max 11 -> bucket 16
    a = _mixed_reqs(cfg, lengths, max_new=5, seed=8)
    b = _mixed_reqs(cfg, lengths, max_new=5, seed=8)
    ServeEngine(model, params, batch_size=2, max_seq=48, telemetry=False,
                prefill_buckets="off").serve(a)
    eng = ServeEngine(model, params, batch_size=2, max_seq=48,
                      telemetry=False, prefill_buckets="auto")
    st = eng.serve(b)
    for ra, rb in zip(a, b):
        assert ra.output == rb.output
    assert st["prompt_tokens"] == sum(lengths)


def test_bounded_prefill_compiles_under_mixed_traffic(dense):
    """THE acceptance gate: >= 32 distinct prompt lengths compile at most
    len(buckets) prefill executables, and compile activity is surfaced in
    run stats and telemetry counters."""
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, batch_size=4, max_seq=64)
    lengths = list(range(2, 34))                 # 32 distinct lengths
    stats = eng.serve(_mixed_reqs(cfg, lengths, max_new=2, seed=9))
    assert stats["completed"] == len(lengths)
    n_buckets = len(eng.buckets)
    assert stats["prefill_compiles"] <= n_buckets
    used = {bucket_for(n, eng.buckets) for n in lengths}
    assert stats["prefill_compiles"] == len(used)
    assert stats["decode_compiles"] == 1         # fixed decode shapes
    assert stats["prefill_buckets"] == list(eng.buckets)
    # lifetime TraceStats agrees with the jit wrappers
    assert eng.trace_stats.compiles("prefill") == stats["prefill_compiles"]
    assert eng.trace_stats.calls("prefill") == len(lengths)
    # ... and telemetry carries the same counts on the energy report
    rep = eng.tel.session.report()
    assert rep.counters["compiles/prefill"] == stats["prefill_compiles"]
    assert rep.counters["compiles/decode"] == 1
    assert "compiles/prefill" in stats["counters"]


# ---------------------------------------------------------------------------
# satellite regressions


def test_shed_removes_request_from_queue():
    """A shed request must never be pop()-ed into a slot."""
    q = RequestQueue()
    r1 = Request(1, np.arange(4, dtype=np.int32), max_new_tokens=2)
    r2 = Request(2, np.arange(4, dtype=np.int32), max_new_tokens=2)
    q.push(r1)
    q.push(r2)
    q.shed(r1, "shed")
    assert len(q) == 1 and q.n_shed == 1
    assert r1.done and r1.finish_reason == "shed"
    assert q.pop() is r2                        # r1 can't re-enter a slot
    assert not q


def test_shed_after_pop_is_idempotent():
    q = RequestQueue()
    r = Request(1, np.arange(4, dtype=np.int32), max_new_tokens=2)
    q.push(r)
    q.shed(q.pop(), "shed-cap")                 # already out of the deque
    assert len(q) == 0 and q.n_shed == 1


def test_queued_tokens_counts_prompt_and_budget():
    q = RequestQueue()
    q.push(Request(1, np.arange(5, dtype=np.int32), max_new_tokens=7))
    q.push(Request(2, np.arange(3, dtype=np.int32), max_new_tokens=2))
    assert q.queued_tokens() == (5 + 7) + (3 + 2)


def test_shed_prices_prefill_at_prefill_rate():
    """Prompt tokens ahead are priced at the measured prefill rate, not the
    orders-slower decode rate — otherwise a long queued prompt predicts a
    wait that never happens and sheds requests that would meet their TTL."""
    from repro.core.scheduler import ThroughputStats
    from repro.serve.queue import AdmissionController
    stats = ThroughputStats()
    stats.observe("decode", 50, 1.0)        # 50 tok/s decode
    stats.observe("prefill", 5000, 1.0)     # a whole prompt per call
    adm = AdmissionController(stats=stats)
    req = Request(1, np.arange(8, dtype=np.int32), max_new_tokens=8,
                  ttl_s=2.0)
    # 8 decode + 500 prompt tokens ahead: 0.16s + 0.1s, well inside the TTL
    assert not adm.should_shed(req, 8, 500)
    # ... while decode-rate pricing would have (wrongly) shed it
    assert stats.predicted_wait_s(8 + 500) > req.ttl_s
    # a genuinely long prefill backlog still sheds
    assert adm.should_shed(req, 8, 500_000)
    # unmeasured prefill rate: prompts contribute nothing (optimistic,
    # same stance as the unmeasured-decode case)
    s2 = ThroughputStats()
    s2.observe("decode", 50, 1.0)
    assert not AdmissionController(stats=s2).should_shed(req, 8, 10_000)


def test_static_filler_rows_stay_out_of_attribution(dense):
    """Fewer requests than batch_size: filler rows decode as dead weight but
    contribute nothing to throughput stats or per-request joules."""
    cfg, model, params = dense
    eng = ServeEngine(model, params, batch_size=4, max_seq=48)
    reqs = _mixed_reqs(cfg, [6, 9], max_new=4, seed=10)
    stats = eng.serve(reqs)
    assert stats["prompt_tokens"] == 15          # true tokens, no pad/filler
    # all board energy lands on the two real requests
    parts = sum(r.energy_j for r in reqs)
    assert stats["energy_j"] > 0
    assert abs(stats["energy_j"] - parts) <= 1e-6 + 0.01 * stats["energy_j"]
    # measured decode throughput counts active rows, not the padded batch:
    # 2 real rows per step, never the 4 the filler-padded batch decodes
    assert eng.stats.totals["decode"] == 2 * stats["decode_steps"]
    assert eng.stats.totals["prefill"] == 15
    assert eng.stats.rate("decode") > 0
