"""Paper Sec. 6.1 (HCW'25 use case): heterogeneous two-resource scheduling.

Task chains placed across p-core/e-core classes under time vs energy vs EDP
objectives; derived column compares against the best single-class baseline.
"""
from benchmarks.common import emit, time_fn
from repro.core import hw
from repro.core.scheduler import HeterogeneousScheduler, ResourceClass, Task


def run():
    classes = [
        ResourceClass("p-cores", hw.RYZEN_7945HX, 4, efficiency=0.8),
        ResourceClass("e-cores", hw.RYZEN_AI_HX370, 8, efficiency=0.7),
    ]
    tasks = []
    for c in range(4):  # four chains of six tasks
        for i in range(6):
            deps = (f"c{c}t{i-1}",) if i else ()
            tasks.append(Task(f"c{c}t{i}", flops=2e12, deps=deps))

    for obj in ("time", "energy", "edp"):
        sched = HeterogeneousScheduler(classes, obj)
        t = time_fn(lambda: sched.schedule(tasks), warmup=0, iters=3)
        _, stats = sched.schedule(tasks)
        base, bstats = HeterogeneousScheduler(classes[:1], "time"), None
        _, bstats = base.schedule(tasks)
        speedup = bstats["makespan_s"] / stats["makespan_s"]
        emit(f"sched/{obj}", t,
             f"makespan={stats['makespan_s']:.1f}s;"
             f"energy={stats['energy_j']:.0f}J;vs_pcore_only={speedup:.2f}x")


if __name__ == "__main__":
    run()
