"""Jit'd wrappers + int8 quantized-linear op built on the DPA4 kernel.

The quantized linear (per-channel symmetric int8 weights, dynamic per-token
int8 activations) is the energy-oriented compute path: DPA4 doubles op/s
over DPA2 on every DALEK CPU (paper Fig. 5) and the same 2x holds for the
MXU's int8 path.
"""
import jax.numpy as jnp

from repro.core.tracing import TraceStats, counting_jit
from repro.kernels.dpa_matmul.dpa_matmul import dpa_matmul

#: module-level compile accounting for the jitted entry points
stats = TraceStats()


def _matmul(a, b, variant="dpa2", interpret=False):
    return dpa_matmul(a, b, variant=variant, interpret=interpret)


matmul = counting_jit(_matmul, "dpa/matmul", stats,
                      static_argnames=("variant", "interpret"))


def quantize_int8(x, axis):
    """Symmetric int8 quantization along ``axis``. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _quantized_linear(x, w, interpret=False):
    """x: [M,K] fp; w: [K,N] fp -> [M,N] f32 via int8 DPA4 kernel."""
    xq, xs = quantize_int8(x, axis=1)          # per-token
    wq, ws = quantize_int8(w, axis=0)          # per-out-channel
    acc = dpa_matmul(xq, wq, variant="dpa4", interpret=interpret)
    return acc.astype(jnp.float32) * xs * ws


quantized_linear = counting_jit(_quantized_linear, "dpa/quantized_linear",
                                stats, static_argnames=("interpret",))
