"""dalek-lint core: findings, the rule registry, pragma suppression, and
the file driver.

The analyzer is pure stdlib ``ast``: each rule is a class with a ``DLK###``
code and a kebab-case slug, registered via :func:`register`, that inspects
one :class:`ModuleContext` (parsed tree + parent links + shared caches like
the module's jit-wrapped names) and yields :class:`Finding`s. Suppression
is line-based pragmas::

    x = np.asarray(cur)  # dalek: allow[host-sync] one fetch per step

A pragma on its own comment line covers the next statement line; the token
inside ``allow[...]`` is a rule slug, a DLK code, or ``all``. Suppressed
findings are kept (and counted) but never fail the run — the CI gate
regresses on the *non-suppressed* count.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

PRAGMA_RE = re.compile(r"#\s*dalek:\s*allow\[([A-Za-z0-9_,\- *]+)\]")

#: basenames treated as test files (rules with ``skip_tests`` pass them by:
#: tests jit reference computations and sync on results *by design*)
_TEST_RE = re.compile(r"^(test_.*|conftest)\.py$")


@dataclasses.dataclass
class Finding:
    code: str            # "DLK001"
    rule: str            # "bare-jit"
    path: str            # posix, as given on the command line
    line: int
    col: int
    message: str
    line_text: str = ""
    #: last source line of the reported node's *header* — a pragma anywhere
    #: in [line, end_line] suppresses (wrapped calls span several lines)
    end_line: int = 0
    suppressed: bool = False
    baselined: bool = False

    @property
    def active(self) -> bool:
        return not (self.suppressed or self.baselined)

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def render(self) -> str:
        note = (" (suppressed)" if self.suppressed
                else " (baselined)" if self.baselined else "")
        return f"{self.location}: {self.code} [{self.rule}] {self.message}{note}"

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def key(self):
        """Baseline identity: line numbers churn, source text doesn't."""
        return (self.code, self.path, self.line_text.strip())


class Rule:
    """One check. Subclasses set ``code``/``name`` and implement ``check``."""

    code: str = "DLK000"
    name: str = "unnamed"
    #: rules that meter production discipline skip test files: tests jit
    #: fresh references and sync on results on purpose
    skip_tests: bool = False

    def check(self, ctx: "ModuleContext") -> Iterator[Finding]:
        raise NotImplementedError


REGISTRY: List[type] = []


def register(cls):
    REGISTRY.append(cls)
    return cls


def all_rules() -> List[Rule]:
    return [cls() for cls in REGISTRY]


def rule_codes() -> List[str]:
    return sorted(cls.code for cls in REGISTRY)


# ---------------------------------------------------------------------------
# AST helpers shared by rules


def qualname(node) -> str:
    """Dotted source name for Name/Attribute chains ("self.pages.alloc");
    empty string for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def root_name(node) -> str:
    """Base variable of an expression: ``a.b[c].d`` -> "a"."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def is_jax_jit(node, ctx: "ModuleContext") -> bool:
    """True for a reference to ``jax.jit`` (or a bare ``jit`` imported
    from jax)."""
    qn = qualname(node)
    return qn == "jax.jit" or (qn == "jit" and "jit" in ctx.jax_imports)


def is_partial_jit(call, ctx: "ModuleContext") -> bool:
    """``functools.partial(jax.jit, ...)``."""
    return (isinstance(call, ast.Call)
            and qualname(call.func) in ("functools.partial", "partial")
            and bool(call.args) and is_jax_jit(call.args[0], ctx))


def is_counting_jit(node) -> bool:
    qn = qualname(node)
    return qn == "counting_jit" or qn.endswith(".counting_jit")


def literal_names(node) -> List[str]:
    """String literals inside a tuple/list/constant node."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def literal_ints(node) -> List[int]:
    """Int literals inside a tuple/list/constant node."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)
                and not isinstance(e.value, bool)]
    return []


class ModuleContext:
    """One parsed module + the caches rules share."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.is_test = bool(_TEST_RE.match(Path(path).name))
        #: set by :class:`repro.analysis.project.ProjectIndex` — dotted module
        #: name, per-module import table, and the owning whole-program index.
        #: ``analyze_source`` builds a one-module index, so interprocedural
        #: rules see a project even in single-file mode.
        self.module_name: str = Path(path).stem
        self.import_table: Dict[str, str] = {}
        self.project = None  # type: Optional["repro.analysis.project.ProjectIndex"]
        self._parents: Dict[int, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[id(child)] = parent
        #: names ``from jax import ...`` bound in this module
        self.jax_imports: Set[str] = {
            alias.asname or alias.name
            for node in ast.walk(tree) if isinstance(node, ast.ImportFrom)
            and node.module == "jax" for alias in node.names}
        self._jitted_names: Optional[Set[str]] = None
        self._functions: Optional[List[ast.FunctionDef]] = None

    # -- structure -----------------------------------------------------------

    def parent(self, node) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def ancestors(self, node) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing(self, node, kinds) -> Optional[ast.AST]:
        for anc in self.ancestors(node):
            if isinstance(anc, kinds):
                return anc
        return None

    def enclosing_function(self, node):
        return self.enclosing(node, (ast.FunctionDef, ast.AsyncFunctionDef))

    def enclosing_class(self, node) -> Optional[ast.ClassDef]:
        return self.enclosing(node, ast.ClassDef)

    @property
    def functions(self) -> List[ast.FunctionDef]:
        if self._functions is None:
            self._functions = [n for n in ast.walk(self.tree)
                               if isinstance(n, (ast.FunctionDef,
                                                 ast.AsyncFunctionDef))]
        return self._functions

    # -- jit tracking --------------------------------------------------------

    @property
    def jitted_names(self) -> Set[str]:
        """Plain names and attribute names bound to jit-wrapped callables
        (``f = jax.jit(...)``, ``self._decode = counting_jit(...)``, and
        defs decorated with ``@jax.jit``/``@partial(jax.jit, ...)``)."""
        if self._jitted_names is not None:
            return self._jitted_names
        names: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                call = node.value
                if (is_jax_jit(call.func, self) or is_counting_jit(call.func)
                        or is_partial_jit(call, self)):
                    for tgt in node.targets:
                        for t in (tgt.elts if isinstance(tgt, ast.Tuple)
                                  else [tgt]):
                            if isinstance(t, ast.Name):
                                names.add(t.id)
                            elif isinstance(t, ast.Attribute):
                                names.add(t.attr)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if is_jax_jit(dec, self) or is_partial_jit(dec, self):
                        names.add(node.name)
        self._jitted_names = names
        return names

    def calls_jitted(self, func_node: ast.FunctionDef) -> bool:
        """Does this function directly call a known jit-wrapped name?"""
        jitted = self.jitted_names
        for node in ast.walk(func_node):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id in jitted:
                    return True
                if isinstance(f, ast.Attribute) and f.attr in jitted:
                    return True
        return False

    # -- findings ------------------------------------------------------------

    def finding(self, rule: Rule, node, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        text = self.lines[line - 1] if 0 < line <= len(self.lines) else ""
        # pragma span: every line of the *enclosing statement* for
        # expression findings (a wrapped call can carry its allow[] on the
        # closing-paren line), but only the *header* for statements with a
        # body (an allow[] inside an if/with body must not blanket-suppress
        # the whole block)
        span = node
        while span is not None and not isinstance(span, ast.stmt):
            span = self.parent(span)
        span = span or node
        end = getattr(span, "end_lineno", None) or line
        body = getattr(span, "body", None)
        if isinstance(body, list) and body and hasattr(body[0], "lineno"):
            end = max(line, body[0].lineno - 1)
        return Finding(code=rule.code, rule=rule.name, path=self.path,
                       line=line, col=col, message=message,
                       line_text=text, end_line=end)


# ---------------------------------------------------------------------------
# suppression pragmas


def _pragma_rules(line: str) -> Set[str]:
    out: Set[str] = set()
    for m in PRAGMA_RE.finditer(line):
        out |= {tok.strip().lower() for tok in m.group(1).split(",")
                if tok.strip()}
    return out


def suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """line number (1-based) -> allowed rule tokens. A pragma on a pure
    comment line also covers the following line."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        toks = _pragma_rules(line)
        if not toks:
            continue
        out.setdefault(i, set()).update(toks)
        if line.lstrip().startswith("#"):
            out.setdefault(i + 1, set()).update(toks)
    return out


def _is_allowed(finding: Finding, allowed: Dict[int, Set[str]]) -> bool:
    last = max(finding.end_line, finding.line)
    for line in range(finding.line, last + 1):
        toks = allowed.get(line, ())
        if toks and ("all" in toks or "*" in toks
                     or finding.rule in toks
                     or finding.code.lower() in toks):
            return True
    return False


# ---------------------------------------------------------------------------
# driver

#: parsed-AST cache keyed by source content hash — project mode parses the
#: whole tree once per *content*, so repeated runs (and the same file reached
#: through several roots) are free
_AST_CACHE: Dict[str, ast.Module] = {}
_AST_CACHE_MAX = 2048


def parse_cached(source: str) -> ast.Module:
    key = hashlib.sha256(source.encode("utf-8", "replace")).hexdigest()
    tree = _AST_CACHE.get(key)
    if tree is None:
        tree = ast.parse(source)
        if len(_AST_CACHE) >= _AST_CACHE_MAX:
            _AST_CACHE.clear()
        _AST_CACHE[key] = tree
    return tree


def check_module(ctx: ModuleContext,
                 rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run every (selected) rule over one prepared module context."""
    allowed = suppressions(ctx.lines)
    findings: List[Finding] = []
    seen = set()
    for rule in (rules if rules is not None else all_rules()):
        if rule.skip_tests and ctx.is_test:
            continue
        for f in rule.check(ctx):
            # one finding per (rule, line): compound expressions (e.g.
            # int(np.asarray(x)[0])) must not double-report
            if (f.code, f.line) in seen:
                continue
            seen.add((f.code, f.line))
            if _is_allowed(f, allowed):
                f.suppressed = True
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings


def analyze_source(source: str, path: str,
                   rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run every (selected) rule over one module's source."""
    try:
        tree = parse_cached(source)
    except SyntaxError as e:
        return [Finding(code="DLK000", rule="parse-error", path=path,
                        line=e.lineno or 1, col=e.offset or 0,
                        message=f"could not parse: {e.msg}")]
    ctx = ModuleContext(path, source, tree)
    # a one-module project: interprocedural rules work on single files too
    from repro.analysis.project import ProjectIndex
    ProjectIndex([ctx])
    return check_module(ctx, rules)


def iter_py_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


def select_rules(select: Optional[Iterable[str]] = None,
                 ignore: Optional[Iterable[str]] = None) -> List[Rule]:
    def norm(vals):
        return {v.strip().lower() for v in vals or () if v.strip()}

    sel, ign = norm(select), norm(ignore)

    def match(rule, toks):
        return rule.code.lower() in toks or rule.name in toks

    rules = [r for r in all_rules() if not sel or match(r, sel)]
    return [r for r in rules if not match(r, ign)]


def analyze_paths(paths: Iterable[str],
                  select: Optional[Iterable[str]] = None,
                  ignore: Optional[Iterable[str]] = None) -> List[Finding]:
    rules = select_rules(select, ignore)
    findings: List[Finding] = []
    for file in iter_py_files(paths):
        try:
            source = file.read_text()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding(
                code="DLK000", rule="parse-error", path=file.as_posix(),
                line=1, col=0, message=f"could not read: {e}"))
            continue
        findings.extend(analyze_source(source, file.as_posix(), rules))
    return findings
