"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)
and the shared ``--json`` row dump every bench feeds the CI perf-trajectory
artifact through."""
import json
import time

import jax


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time per call in seconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name, seconds, derived=""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


class BenchRows:
    """Collects emitted rows so a bench can dump them as the JSON artifact
    CI uploads per run (the ``bench_energy_platform`` pattern).

    Extra keyword fields ride along in the JSON row — the cross-run
    regression gate (``benchmarks.regression_gate``) reads ``compiles``
    (jit executable counts, gated at zero increase) next to ``us_per_call``
    (gated at a relative slowdown threshold)."""

    def __init__(self):
        self.rows = {}

    def record(self, name, seconds, derived="", **extra):
        emit(name, seconds, derived)
        self.rows[name] = {"us_per_call": seconds * 1e6, "derived": derived,
                           **extra}

    def dump(self, json_path):
        if json_path:
            with open(json_path, "w") as f:
                json.dump(self.rows, f, indent=2, sort_keys=True)
