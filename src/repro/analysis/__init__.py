"""dalek-lint: AST static analysis for the repo's own discipline.

Importing the package registers every rule module; ``python -m
repro.analysis`` runs the CLI. Rules (see ``--list-rules``):

=======  =====================  ==============================================
DLK001   bare-jit               jax.jit outside counting_jit (compile gate blind)
DLK002   host-sync              device->host sync inside an engine hot loop
DLK003   traced-branch          python control flow on a traced value in jit
DLK004   jit-kwargs             static/donate argnums wiring errors
DLK005   untagged-energy        MonitorSession.sample with no region()/tags
DLK006   refcount-pairing       PagePool block acquired but not consumed/released
DLK007   unclosed-span          obs.Tracer span opened but never ended
DLK008   state-reset-pairing    slot released for reuse without adapter reset
DLK009   interproc-host-sync    device value synced inside a helper called from a hot loop
DLK010   dtype-drift            carry returned in a drifted dtype (decode retrace)
DLK011   ownership-handoff      block/span handle passed to a non-consuming callee
DLK012   unguarded-shared-state field accessed both under self._lock and bare
=======  =====================  ==============================================

DLK009–DLK012 are interprocedural: they read function summaries off a
:class:`repro.analysis.project.ProjectIndex` (``--project`` on the CLI;
single-file runs get a one-module index automatically).
"""
from repro.analysis.core import (Finding, ModuleContext,  # noqa: F401
                                 Rule, all_rules, analyze_paths,
                                 analyze_source, check_module, rule_codes,
                                 select_rules)
from repro.analysis.project import (FunctionSummary,  # noqa: F401
                                    ProjectIndex, analyze_project)
# importing the rule modules populates the registry
from repro.analysis import (rules_dtype, rules_energy,  # noqa: F401
                            rules_host, rules_interproc, rules_jit,
                            rules_obs, rules_race, rules_refcount,
                            rules_state)
from repro.analysis.baseline import DEFAULT_BASELINE  # noqa: F401
