"""dalek-lint: AST static analysis for the repo's own discipline.

Importing the package registers every rule module; ``python -m
repro.analysis`` runs the CLI. Rules (see ``--list-rules``):

=======  =================  ==================================================
DLK001   bare-jit           jax.jit outside counting_jit (compile gate blind)
DLK002   host-sync          device->host sync inside an engine hot loop
DLK003   traced-branch      python control flow on a traced value in jit
DLK004   jit-kwargs         static/donate argnums wiring errors
DLK005   untagged-energy    MonitorSession.sample with no region()/tags
DLK006   refcount-pairing   PagePool block acquired but not consumed/released
DLK007   unclosed-span      obs.Tracer span opened but never ended
DLK008   state-reset-pairing  slot released for reuse without adapter reset
=======  =================  ==================================================
"""
from repro.analysis.core import (Finding, ModuleContext,  # noqa: F401
                                 Rule, all_rules, analyze_paths,
                                 analyze_source, rule_codes, select_rules)
# importing the rule modules populates the registry
from repro.analysis import (rules_energy, rules_host,  # noqa: F401
                            rules_jit, rules_obs, rules_refcount,
                            rules_state)
from repro.analysis.baseline import DEFAULT_BASELINE  # noqa: F401
