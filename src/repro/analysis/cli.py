"""dalek-lint command line.

    PYTHONPATH=src python -m repro.analysis [opts] [paths...]

Exit status is 1 iff any *active* finding remains (not pragma-suppressed,
not baselined when --baseline is given). ``--gate-json`` writes rows the
perf-trajectory gate consumes: any increase in a row's ``findings``
count across runs fails CI.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.analysis import baseline as baseline_mod
from repro.analysis.core import (Finding, all_rules, analyze_paths,
                                 rule_codes)
from repro.analysis.project import analyze_project

DEFAULT_PATHS = ["src", "benchmarks", "examples", "tests"]


def _summary(findings: List[Finding]) -> Dict[str, int]:
    out = {"total": 0, "active": 0, "suppressed": 0, "baselined": 0}
    for f in findings:
        out["total"] += 1
        if f.suppressed:
            out["suppressed"] += 1
        elif f.baselined:
            out["baselined"] += 1
        else:
            out["active"] += 1
    return out


def gate_rows(findings: List[Finding]) -> Dict[str, Dict[str, int]]:
    """Zero-filled per-rule rows + a total, in regression-gate row shape.
    Zero rows matter: a rule that has never fired still produces a row, so
    its first firing is an *increase* on an existing row, which gates."""
    rows = {f"analysis/{code}": {"findings": 0} for code in rule_codes()}
    rows["analysis/total"] = {"findings": 0}
    for f in findings:
        if not f.active:
            continue
        rows[f"analysis/{f.code}"]["findings"] += 1
        rows["analysis/total"]["findings"] += 1
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="dalek-lint: AST checks for the repo's jit/energy/"
                    "paging discipline")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--project", action="append", default=None,
                    metavar="DIR", help="whole-program mode: build one "
                    "ProjectIndex over DIR (repeatable; positional paths "
                    "join the same project) so interprocedural rules "
                    "resolve calls across modules")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE", help="only these rules (code or slug, "
                    "comma-separable, repeatable)")
    ap.add_argument("--ignore", action="append", default=None,
                    metavar="RULE", help="drop these rules")
    ap.add_argument("--baseline", action="store_true",
                    help="tolerate findings recorded in the baseline file")
    ap.add_argument("--baseline-file", default=None,
                    help="baseline path (default: packaged baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current non-suppressed findings as the "
                    "baseline and exit 0")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--gate-json", default=None, metavar="FILE",
                    help="write per-rule finding counts as regression-gate "
                    "bench rows")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed/baselined findings")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(all_rules(), key=lambda r: r.code):
            doc = (rule.__doc__ or "").strip().splitlines()[0]
            print(f"{rule.code}  {rule.name:18s} {doc}")
        return 0

    def split(vals):
        return [tok for v in vals or () for tok in v.split(",") if tok]

    if args.project:
        roots = args.project + (args.paths or [])
        findings = analyze_project(roots, select=split(args.select),
                                   ignore=split(args.ignore))
    else:
        paths = args.paths or DEFAULT_PATHS
        findings = analyze_paths(paths, select=split(args.select),
                                 ignore=split(args.ignore))

    bl_path = args.baseline_file or baseline_mod.DEFAULT_BASELINE
    if args.write_baseline:
        doc = baseline_mod.save(findings, bl_path)
        print(f"wrote {len(doc['findings'])} baseline entries to {bl_path}")
        return 0
    if args.baseline:
        baseline_mod.apply(findings, baseline_mod.load(bl_path))

    summary = _summary(findings)
    if args.as_json:
        print(json.dumps({"summary": summary,
                          "findings": [f.as_dict() for f in findings]},
                         indent=2))
    else:
        for f in findings:
            if f.active or args.show_suppressed:
                print(f.render())
        if summary["total"]:
            print(f"-- {summary['active']} active, "
                  f"{summary['suppressed']} suppressed, "
                  f"{summary['baselined']} baselined "
                  f"({summary['total']} total)", file=sys.stderr)

    if args.gate_json:
        with open(args.gate_json, "w") as fh:
            json.dump(gate_rows(findings), fh, indent=2, sort_keys=True)

    return 1 if summary["active"] else 0


if __name__ == "__main__":
    sys.exit(main())
