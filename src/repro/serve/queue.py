"""Request queue + energy-aware admission control for the serving engine.

Requests enter a FIFO queue; the admission controller decides, per engine
iteration, how many may occupy decode slots. Under a node power cap it
consults the DVFS model (``core.energy.cap_frequency``) for the highest
sustainable frequency and limits concurrency so the modeled average power
stays under the cap; requests whose predicted queue wait (from measured
throughput, ``core.scheduler.ThroughputStats``) exceeds their TTL are shed.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, List, Optional

import numpy as np

from repro.core.energy import DvfsState, ServePowerModel, cap_frequency
from repro.core.hw import DeviceSpec
from repro.core.scheduler import ThroughputStats


@dataclasses.dataclass
class Request:
    """One generation request. ``output`` accumulates sampled token ids;
    ``energy_j`` accumulates this request's share of board energy from the
    tag-bus attribution (paper Sec. 4.1)."""

    req_id: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    frames: Optional[np.ndarray] = None  # audio: [enc_seq, D] encoder frames
    ttl_s: Optional[float] = None   # shed if predicted wait exceeds this
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str = ""   # "length" | "eos" | "shed" | "capacity" | "pages"
    energy_j: float = 0.0
    prefill_s: float = 0.0
    decode_steps: int = 0
    cached_prompt_tokens: int = 0   # prompt span served from the prefix cache

    @property
    def n_generated(self) -> int:
        return len(self.output)


class RequestQueue:
    """FIFO admission queue with shed support."""

    def __init__(self):
        self._q: Deque[Request] = collections.deque()
        self.n_shed = 0

    def push(self, req: Request):
        self._q.append(req)

    def pop(self) -> Request:
        return self._q.popleft()

    def peek(self) -> Request:
        return self._q[0]

    def shed(self, req: Request, reason: str = "shed"):
        """Mark ``req`` shed AND drop it from the queue: a shed request must
        never be ``pop()``-ed into a slot (callers used to need a separate
        ``remove()``; forgetting it re-admitted dead requests)."""
        req.done = True
        req.finish_reason = reason
        self.n_shed += 1
        try:
            self._q.remove(req)
        except ValueError:
            pass    # already popped (e.g. shed straight from a pop())

    def queued_tokens(self, cached_tokens_fn=None) -> int:
        """Token budget waiting in the queue (admission wait estimate):
        prompt tokens still to prefill plus the generation budget — counting
        only ``max_new_tokens`` undercounts the wait and sheds too late.

        ``cached_tokens_fn(req)`` (optional) returns the prompt span the
        prefix cache is expected to serve without compute; pricing queued
        prompts gross of cache hits over-sheds warm-prefix traffic, so the
        engine passes its prefix-cache probe here."""
        if cached_tokens_fn is None:
            return sum(len(r.prompt) + r.max_new_tokens for r in self._q)
        return sum(
            max(0, len(r.prompt) - cached_tokens_fn(r)) + r.max_new_tokens
            for r in self._q)

    def snapshot(self) -> List[Request]:
        """Queue contents in FIFO order (for shed walks)."""
        return list(self._q)

    def remove(self, req: Request):
        self._q.remove(req)

    def __len__(self):
        return len(self._q)

    def __bool__(self):
        return bool(self._q)


class AdmissionController:
    """Energy-aware admission: concurrency under a power cap + TTL shedding.

    With no cap every free slot is filled (work-conserving). With a cap:

    1. ``cap_frequency`` picks the highest DVFS state whose modeled step
       power at full batch fits the cap (frequency is set per-node, not
       per-slot).
    2. At that frequency, concurrency is limited to the largest ``n`` whose
       duty-cycle-average power (``ServePowerModel.avg_power_w``) fits the
       cap — admitting more requests raises utilization and therefore power.
    3. Requests whose predicted wait (queued tokens / measured decode rate)
       exceeds their TTL are shed instead of queued indefinitely.
    4. ``max_slots_fn`` / ``should_shed_fn`` override steps 2 and 3 wholesale
       — the injection point the trace-replay harness (``repro.tracestore``)
       uses to regression-test policy variants against recorded power
       without subclassing the controller.
    """

    def __init__(self, power_model: Optional[ServePowerModel] = None,
                 power_cap_w: Optional[float] = None,
                 stats: Optional[ThroughputStats] = None,
                 max_slots_fn: Optional[Callable[[int], int]] = None,
                 should_shed_fn: Optional[Callable[["Request", int],
                                                   bool]] = None):
        self.pm = power_model
        self.cap_w = power_cap_w
        self.stats = stats or ThroughputStats()
        self.max_slots_fn = max_slots_fn
        self.should_shed_fn = should_shed_fn

    def dvfs(self, batch_size: int) -> Optional[DvfsState]:
        """DVFS state sustaining the cap at full concurrency (None = f_max)."""
        if self.cap_w is None or self.pm is None:
            return None
        return cap_frequency(self.cap_w, self.pm.terms(batch_size),
                             self.pm.dev)

    def apply_dvfs(self, batch_size: int) -> Optional[DvfsState]:
        """Resolve and install the capped DVFS state on the power model."""
        st = self.dvfs(batch_size)
        if self.pm is not None:
            self.pm.dvfs = st
        return st

    def max_slots(self, batch_size: int) -> int:
        """Largest concurrency whose modeled average power fits the cap."""
        if self.max_slots_fn is not None:
            return self.max_slots_fn(batch_size)
        if self.cap_w is None or self.pm is None:
            return batch_size
        n = 0
        for i in range(1, batch_size + 1):
            if self.pm.avg_power_w(i) <= self.cap_w:
                n = i
        return n

    def admit(self, n_active: int, batch_size: int) -> bool:
        return n_active < min(batch_size, self.max_slots(batch_size))

    def should_shed(self, req: Request, tokens_ahead: int,
                    prefill_tokens_ahead: int = 0) -> bool:
        """Shed when the predicted wait for the work in front of this
        request exceeds its TTL. The two phases are priced separately:
        ``tokens_ahead`` (decode budgets) at the measured decode rate and
        ``prefill_tokens_ahead`` (prompt tokens still to prefill) at the
        measured prefill rate — prefill moves a whole prompt per call, so
        pricing prompts at the ~orders-slower decode rate would predict
        waits that never happen and shed requests that would meet their
        TTL. Unmeasured prefill contributes nothing (optimistic, like the
        unmeasured-decode case). A request with nothing ahead of it is
        never shed — it would start immediately. Injected ``should_shed_fn``
        policies receive the decode-budget count only, unchanged from
        before prompt accounting existed."""
        if self.should_shed_fn is not None:
            return self.should_shed_fn(req, tokens_ahead)
        if req.ttl_s is None or tokens_ahead + prefill_tokens_ahead <= 0:
            return False
        if self.stats.rate("decode") <= 0:
            return False       # nothing measured yet: admit optimistically
        wait = self.stats.predicted_wait_s(tokens_ahead)
        prefill_rate = self.stats.rate("prefill")
        if prefill_tokens_ahead > 0 and prefill_rate > 0:
            wait += prefill_tokens_ahead / prefill_rate
        return wait > req.ttl_s
