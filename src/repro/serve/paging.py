"""Paged KV-cache bookkeeping + radix prefix-cache sharing (host side).

The fixed per-slot KV tensors bound concurrency by worst-case sequence
length: a slot owns ``max_seq`` cache positions whether its request uses 9
or 9000. Paging replaces that with fixed-size KV *blocks* (``block_size``
positions each) drawn from one shared pool; a slot's cache is a *block
table* — an ordered list of block ids — and the jitted steps gather the
slot's logical view through that indirection (``models.common.paged_gather``/
``paged_scatter_*``). Blocks are reference-counted: a block shared by N
owners is stored once, and copy-on-write (``ensure_writable``) guarantees a
writer never mutates a block another owner can still read.

On top of the allocator sits a radix/trie prefix cache keyed on token
content at block granularity: production traffic is dominated by shared
system prompts, and a request whose prompt prefix matches cached blocks maps
them into its table (refcount bump, ZERO prefill compute) and only prefills
the unmatched tail. Prefill cost becomes O(distinct prefixes), not
O(requests). Trie nodes hold their own reference, so prefix blocks survive
the request that computed them; when the pool runs dry the engine evicts
LRU trie entries nobody else references.

Invariants the engine relies on:

- block 0 is the reserved *null* block: free slots and unallocated table
  entries point at it, so gather/scatter indices are always in range and
  duplicate scatters land harmlessly in a block nothing ever reads.
- only FULL blocks are ever shared (trie matching is block-granular), so a
  slot's write position — decode append or prefill tail — always lands in a
  block it owns exclusively; ``ensure_writable`` is a defensive backstop,
  not a hot path.
- freed blocks are queued on ``pending_zero`` and zeroed (one jitted
  scatter, engine-side) before reuse, keeping the pool bit-identical to a
  contiguous cache that resets slot rows on release.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["PagePool", "RadixPrefixCache", "resolve_kv_block_size"]


def resolve_kv_block_size(spec, max_seq: int, supported: bool = True):
    """Normalize a ``kv_block_size`` argument.

    ``"auto"``/True -> the largest power-of-two block size <= 32 that
    divides ``max_seq`` (divisibility keeps the paged logical view exactly
    ``max_seq`` positions long — the bit-exactness contract vs. the
    contiguous cache needs identical attention reduction shapes); ``None``/
    ``"off"``/False -> paging disabled (contiguous per-slot cache). An
    explicit int must divide ``max_seq`` and raises otherwise. With
    ``supported=False`` (recurrent families, windowed ring caches) ``auto``
    silently degrades to off; an explicit size raises.
    """
    if spec in (None, False, "off", "none"):
        return None
    if spec in (True, "auto"):
        if not supported:
            return None
        for bs in (32, 16, 8, 4, 2):
            if bs <= max_seq and max_seq % bs == 0:
                return bs
        return None                      # odd max_seq: not worth paging
    bs = int(spec)
    if not supported:
        raise ValueError(
            "this model family cannot use a paged KV cache "
            "(pass kv_block_size='off')")
    if bs < 1 or max_seq % bs != 0:
        raise ValueError(
            f"kv_block_size={bs} must divide max_seq={max_seq} "
            "(the paged view must cover exactly max_seq positions)")
    return bs


@dataclasses.dataclass
class PageStats:
    total_blocks: int = 0
    peak_used: int = 0
    allocs: int = 0
    cow_copies: int = 0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class PagePool:
    """Block allocator + per-slot block tables (host bookkeeping only).

    The device-side pool tensor lives in the engine; this class tracks which
    pool blocks back which slot positions, reference counts, the free list,
    and the ``pending_zero`` queue of freed blocks the engine must scrub
    before reuse.
    """

    NULL = 0

    def __init__(self, n_slots: int, n_slot_blocks: int, n_blocks: int,
                 block_size: int):
        if n_blocks < n_slot_blocks + 1:
            raise ValueError(
                f"pool of {n_blocks} blocks cannot back even one full slot "
                f"({n_slot_blocks} blocks + the reserved null block)")
        self.n_slots = n_slots
        self.n_slot_blocks = n_slot_blocks
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.tables = np.zeros((n_slots, n_slot_blocks), np.int32)
        self.refcount = np.zeros(n_blocks, np.int32)
        self.refcount[self.NULL] = 2**30          # pinned, never allocatable
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))  # pop() -> 1
        self.pending_zero: List[int] = []
        self.stats = PageStats(total_blocks=n_blocks - 1)

    # -- allocation ----------------------------------------------------------

    def free_blocks(self) -> int:
        return len(self._free)

    def used_blocks(self) -> int:
        return self.n_blocks - 1 - len(self._free)

    def blocks_for(self, n_positions: int) -> int:
        """Blocks needed to back ``n_positions`` cache positions."""
        return -(-int(n_positions) // self.block_size)

    def alloc(self) -> Optional[int]:
        """Pop a free block (refcount 1); None when the pool is dry.

        The caller (engine) must flush ``pending_zero`` first — a freed
        block re-enters circulation only after its stale KV is scrubbed.
        """
        if not self._free:
            return None
        blk = self._free.pop()
        self.refcount[blk] = 1
        self.stats.allocs += 1
        self.stats.peak_used = max(self.stats.peak_used, self.used_blocks())
        return blk

    def retain(self, blk: int):
        assert blk != self.NULL
        assert self.refcount[blk] > 0, f"retain of dead block {blk}"
        self.refcount[blk] += 1

    def free(self, blk: int):
        """Drop one reference; a block nobody references returns to the
        free list and is queued for zeroing."""
        if blk == self.NULL:
            return
        assert self.refcount[blk] > 0, f"double free of block {blk}"
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            self._free.append(blk)
            self.pending_zero.append(blk)

    def drain_pending_zero(self) -> List[int]:
        out, self.pending_zero = self.pending_zero, []
        return out

    # -- slot tables ---------------------------------------------------------

    def table_row(self, slot: int) -> np.ndarray:
        return self.tables[slot]

    def slot_blocks(self, slot: int) -> List[int]:
        """Non-null blocks currently mapped by ``slot`` (table order)."""
        row = self.tables[slot]
        return [int(b) for b in row[row != self.NULL]]

    def map_shared(self, slot: int, blocks: Sequence[int]):
        """Map already-populated blocks (a matched prefix) into the head of
        ``slot``'s table, taking one reference each — the zero-compute path
        a prefix-cache hit rides."""
        assert len(blocks) <= self.n_slot_blocks
        for j, blk in enumerate(blocks):
            assert self.tables[slot, j] == self.NULL, (
                f"slot {slot} entry {j} already mapped")
            self.retain(blk)
            self.tables[slot, j] = blk

    def ensure_capacity(self, slot: int, n_positions: int,
                        alloc_fn=None) -> bool:
        """Allocate blocks so positions [0, n_positions) are backed.

        ``alloc_fn`` (default ``self.alloc``) lets the engine interpose
        prefix-cache eviction + pending-zero flushing. Returns False —
        with any partial allocations kept mapped — when the pool is dry.
        """
        alloc_fn = alloc_fn or self.alloc
        for j in range(self.blocks_for(n_positions)):
            if self.tables[slot, j] == self.NULL:
                blk = alloc_fn()
                if blk is None:
                    return False
                self.tables[slot, j] = blk
        return True

    def ensure_writable(self, slot: int, pos: int,
                        alloc_fn=None) -> Tuple[str, int, int]:
        """Make the block holding position ``pos`` exclusively writable.

        Returns one of::

            ("ok",   blk,  -1)   already backed and exclusively owned
            ("new",  blk,  -1)   freshly allocated (engine: nothing to copy)
            ("cow",  src, dst)   was shared: caller must copy src -> dst
            ("oom",  -1,   -1)   pool dry — finish the request (reason
                                 "pages") or defer

        Full-block-only sharing means the "cow" arm is a defensive backstop
        (appends always land in exclusively-owned or fresh blocks), but it
        keeps the allocator honest for any future partial-block sharing
        policy.
        """
        alloc_fn = alloc_fn or self.alloc
        j = pos // self.block_size
        blk = int(self.tables[slot, j])
        if blk == self.NULL:
            new = alloc_fn()
            if new is None:
                return ("oom", -1, -1)
            self.tables[slot, j] = new
            return ("new", new, -1)
        if self.refcount[blk] > 1:
            new = alloc_fn()
            if new is None:
                return ("oom", -1, -1)
            self.tables[slot, j] = new
            self.free(blk)               # drop our shared reference
            self.stats.cow_copies += 1
            return ("cow", blk, new)
        return ("ok", blk, -1)

    def release_slot(self, slot: int):
        """Drop every reference ``slot`` holds and clear its table; blocks
        retained elsewhere (trie, other slots) survive untouched."""
        for j in range(self.n_slot_blocks):
            blk = int(self.tables[slot, j])
            if blk != self.NULL:
                self.free(blk)
                self.tables[slot, j] = self.NULL


# ---------------------------------------------------------------------------
# radix prefix cache


class _TrieNode:
    __slots__ = ("children", "block", "last_used", "parent", "key")

    def __init__(self, parent=None, key=None, block: int = -1):
        self.children: Dict[Tuple[int, ...], _TrieNode] = {}
        self.block = block
        self.last_used = 0
        self.parent = parent
        self.key = key


@dataclasses.dataclass
class PrefixCacheStats:
    hits: int = 0                # prefills that reused >= 1 cached block
    misses: int = 0
    cached_tokens: int = 0       # prompt tokens served from cache (no compute)
    inserted_blocks: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


class RadixPrefixCache:
    """Block-granularity radix tree over prompt token prefixes.

    Each edge is the tuple of ``block_size`` token ids a block holds; the
    node stores the pool block containing that span's KV. ``match`` walks
    the longest fully-matched block chain (always leaving >= 1 prompt token
    for the tail prefill — the next-token logits must still be computed);
    ``insert`` adopts a request's freshly-computed full prompt blocks, the
    trie taking one reference so they outlive the request. ``evict`` frees
    least-recently-used entries nobody else references.
    """

    def __init__(self, block_size: int, pool: PagePool):
        self.bs = block_size
        self.pool = pool
        self.root = _TrieNode()
        self._clock = 0
        self.stats = PrefixCacheStats()

    def _touch(self, node: _TrieNode):
        self._clock += 1
        node.last_used = self._clock

    def _walk(self, tokens: np.ndarray, max_blocks: int, touch: bool):
        node, path = self.root, []
        for j in range(max_blocks):
            key = tuple(int(t) for t in tokens[j * self.bs:(j + 1) * self.bs])
            child = node.children.get(key)
            if child is None:
                break
            if touch:
                self._touch(child)
            path.append(child)
            node = child
        return node, path

    def match(self, tokens: np.ndarray) -> List[int]:
        """Block ids covering the longest cached prefix of ``tokens``,
        capped so at least one token is left for the tail prefill. Records
        hit/miss stats and refreshes LRU stamps; the caller must map the
        returned blocks (``PagePool.map_shared``) before anything else can
        trigger eviction."""
        max_blocks = (len(tokens) - 1) // self.bs
        _, path = self._walk(tokens, max_blocks, touch=True)
        blocks = [n.block for n in path]
        if blocks:
            self.stats.hits += 1
            self.stats.cached_tokens += len(blocks) * self.bs
        else:
            self.stats.misses += 1
        return blocks

    def probe(self, tokens: np.ndarray) -> int:
        """Cached-token count for ``tokens`` without stats/LRU side effects
        (admission + TTL wait estimates)."""
        max_blocks = (max(len(tokens), 1) - 1) // self.bs
        _, path = self._walk(tokens, max_blocks, touch=False)
        return len(path) * self.bs

    def insert(self, tokens: np.ndarray, blocks: Sequence[int]):
        """Adopt the full prompt blocks of a freshly prefilled request:
        ``blocks[j]`` holds KV for tokens [j*bs, (j+1)*bs). Already-cached
        prefixes are kept (first writer wins); each newly adopted block gets
        one trie-owned reference."""
        n_full = len(tokens) // self.bs
        node = self.root
        for j in range(min(n_full, len(blocks))):
            key = tuple(int(t) for t in tokens[j * self.bs:(j + 1) * self.bs])
            child = node.children.get(key)
            if child is None:
                child = _TrieNode(parent=node, key=key, block=int(blocks[j]))
                self.pool.retain(child.block)
                node.children[key] = child
                self.stats.inserted_blocks += 1
            self._touch(child)
            node = child

    # -- eviction ------------------------------------------------------------

    def _evictable(self, node: _TrieNode, out: List[_TrieNode]) -> bool:
        """Post-order: a node is evictable iff nobody but the trie
        references its block AND its whole subtree is evictable (children
        pin their ancestors — a matched chain needs every link)."""
        ok = all([self._evictable(c, out) for c in node.children.values()])
        if node is self.root:
            return ok
        ok = ok and self.pool.refcount[node.block] == 1
        if ok:
            out.append(node)
        return ok

    def evictable_blocks(self) -> int:
        out: List[_TrieNode] = []
        self._evictable(self.root, out)
        return len(out)

    def evict(self, n_blocks: int) -> int:
        """Free up to ``n_blocks`` LRU evictable entries (leaves first —
        removing a node makes its parent a candidate next round). Returns
        the number of blocks actually freed."""
        freed = 0
        while freed < n_blocks:
            victims: List[_TrieNode] = []
            self._evictable(self.root, victims)
            leaves = [n for n in victims if not n.children]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_used)
            del victim.parent.children[victim.key]
            self.pool.free(victim.block)
            self.stats.evictions += 1
            freed += 1
        return freed

    def clear(self):
        """Drop every cached entry (benchmark cold-start): all trie-held
        references return to the pool."""
        def drop(node):
            for c in node.children.values():
                drop(c)
                self.pool.free(c.block)
        drop(self.root)
        self.root = _TrieNode()
        self.stats = PrefixCacheStats()

    def __len__(self):
        n = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    def __bool__(self):
        return True     # __len__ would make an *empty* cache falsy
