"""Checked-in finding baseline.

A baseline lets the linter land with pre-existing findings grandfathered:
entries are keyed on ``(code, path, stripped source line)`` so they
survive line-number churn but die with the offending code. The file is
JSON, sorted, and deterministic — regenerating it on an unchanged tree
is a no-op, which is itself under test.

Policy (ISSUE.md): DLK001, DLK008, DLK009 and DLK010 findings are
*fixed*, never baselined — an unmetered jit, a leaked slot state, a
per-iteration host sync, or a retrace-inducing dtype drift is always a
bug, not a style call. The shipped baseline starts empty and the CI job
(plus the ``test_checked_in_baseline_has_no_*`` tests) keeps it honest.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.core import Finding

#: the checked-in baseline, package-local
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"

Key = Tuple[str, str, str]


def load(path=DEFAULT_BASELINE) -> Set[Key]:
    path = Path(path)
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {(e["code"], e["path"], e["line_text"])
            for e in data.get("findings", [])}


def save(findings: Iterable[Finding], path=DEFAULT_BASELINE) -> Dict:
    """Write the non-suppressed findings as the new baseline. Sorted and
    key-deduplicated so the output is byte-stable for a given tree."""
    keys = sorted({f.key() for f in findings if not f.suppressed})
    doc = {
        "comment": "dalek-lint baseline — regenerate with "
                   "`python -m repro.analysis --write-baseline <paths>`",
        "counts": _counts(keys),
        "findings": [{"code": c, "path": p, "line_text": t}
                     for c, p, t in keys],
    }
    Path(path).write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return doc


def _counts(keys: Iterable[Key]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for code, _, _ in keys:
        out[code] = out.get(code, 0) + 1
    return dict(sorted(out.items()))


def apply(findings: List[Finding], baseline: Set[Key]) -> List[Finding]:
    """Mark findings present in the baseline; returns the same list."""
    for f in findings:
        if not f.suppressed and f.key() in baseline:
            f.baselined = True
    return findings
