"""Trace replay driver: regression-test admission policies offline.

    PYTHONPATH=src python -m repro.launch.replay trace.dkt \
        --requests 8 --max-new 12 --ttl 0.3 --slots 2 [--json rows.json] \
        [--check-determinism]

Loads a recorded ``.dkt`` trace, rebuilds per-node ``TraceSource`` power,
and drives the serve admission pipeline (baseline work-conserving policy
vs a strict single-slot variant, plus ``--cap`` for DVFS power capping)
through the deterministic replay harness. ``--check-determinism`` replays
everything twice and exits non-zero on any divergence (the CI gate).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.core.hw import TPU_V5E
from repro.core.energy import ServePowerModel
from repro.core.scheduler import ThroughputStats
from repro.serve.queue import AdmissionController
from repro.tracestore import ReplayRequest, replay


def _policies(args):
    out = {"baseline": None,
           "strict-1slot": AdmissionController(
               stats=ThroughputStats(), max_slots_fn=lambda b: 1)}
    if args.cap is not None:
        pm = ServePowerModel(args.cap_params, dev=TPU_V5E)
        out[f"cap-{args.cap:.0f}w"] = AdmissionController(
            pm, power_cap_w=args.cap, stats=ThroughputStats())
    return out


def _run(args):
    wl = [ReplayRequest(i, max_new_tokens=args.max_new, ttl_s=args.ttl,
                        arrival_s=i * args.arrival_gap)
          for i in range(args.requests)]
    return replay(args.trace, workload=wl, policies=_policies(args),
                  batch_size=args.slots, step_s=args.step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help=".dkt trace file to replay")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--ttl", type=float, default=None,
                    help="per-request TTL in seconds (enables shedding)")
    ap.add_argument("--arrival-gap", type=float, default=0.02)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--step", type=float, default=0.01,
                    help="simulation tick in seconds")
    ap.add_argument("--cap", type=float, default=None,
                    help="add a DVFS power-capped policy at this wattage")
    ap.add_argument("--cap-params", type=float, default=1e9,
                    help="model size driving the capped policy's power model")
    ap.add_argument("--json", default=None,
                    help="dump the ReplayReport rows as JSON")
    ap.add_argument("--check-determinism", action="store_true",
                    help="replay twice; exit 1 unless reports are identical")
    args = ap.parse_args(argv)

    report = _run(args)
    print(f"replay {report.trace_path}: {report.n_streams} streams, "
          f"{report.n_samples} samples, {report.duration_s:.3f} s")
    for res in report.results:
        print(f"  {res.policy:>14}: {res.attributed_j:9.3f} J attributed "
              f"({res.energy_j:.3f} J trace)  completed={res.completed} "
              f"shed={res.shed}  {res.j_per_token:.4f} J/token"
              + (f"  f={res.dvfs_f_ghz:.2f}GHz" if res.dvfs_f_ghz else ""))
    base = report.results[0].policy
    for res in report.results[1:]:
        d = report.deltas(base, res.policy)
        print(f"  Δ {res.policy} vs {base}: "
              f"{d['attributed_j']:+.3f} J attributed, {d['shed']:+d} shed, "
              f"{d['j_per_token']:+.4f} J/token")

    if args.json:
        rows = {f"replay/{r.policy}": dataclasses.asdict(r)
                for r in report.results}
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2, sort_keys=True)

    if args.check_determinism:
        again = _run(args)
        if again != report:
            print("determinism check FAILED: second replay diverged")
            raise SystemExit(1)
        print("determinism check OK: two replays produced identical reports")
    return report


if __name__ == "__main__":
    main()
