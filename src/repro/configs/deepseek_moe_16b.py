"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6, first layer
dense [arXiv:2401.06066; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    num_experts=64, experts_per_token=6, num_shared_experts=2,
    moe_d_ff=1408, first_k_dense=1,
    source="arXiv:2401.06066",
)

SMOKE_CONFIG = CONFIG.replace(
    name="deepseek-moe-16b-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=8, d_ff=64, vocab_size=512, head_dim=16,
    num_experts=8, experts_per_token=2, num_shared_experts=1, moe_d_ff=64,
)
