"""AdamW + schedules, pure JAX. Optimizer states inherit the parameter
sharding (FSDP over ``data``), so memory scales down with the mesh."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


def lr_schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def abstract_opt_state(params_sds) -> OptState:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(sds, params_sds),
                    v=jax.tree.map(sds, params_sds),
                    step=jax.ShapeDtypeStruct((), jnp.int32))


def global_norm(tree):
    return jnp.sqrt(jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), tree, 0.0))


def adamw_update(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
