"""Runs the multi-device test files in a subprocess with 8 host devices.

The main pytest process sees 1 CPU device (smoke tests must run unsharded,
per the dry-run contract), so the sharded-parity suites
(test_distributed.py, test_moe_parallel.py, the guarded test in
test_compress.py) would otherwise be skipped. This wrapper gives them a
dedicated interpreter with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Forced host devices only emulate the device *count*: the sharded collective
paths need a real multi-device runtime, and on a single-device machine the
respawned suites fail inside XLA rather than exercising the parity checks.
They are skipped (not failed) there, with the device count in the reason, so
single-device CI stays green while multi-device hosts still run them.
"""
import os
import pathlib
import subprocess
import sys

import jax
import pytest

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent


@pytest.mark.parametrize("target", [
    "tests/test_moe_parallel.py",
    "tests/test_compress.py::test_compressed_psum_matches_fp32_within_tolerance",
    "tests/test_distributed.py",
])
def test_multidevice(target):
    n_dev = jax.device_count()
    if n_dev < 2:
        pytest.skip(
            f"sharded-parity suite needs a real multi-device runtime; this "
            f"host exposes {n_dev} device(s) and forced host devices do not "
            f"exercise the sharded collective paths")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", target, "-q", "--no-header", "-p",
         "no:cacheprovider"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"\n{r.stdout[-3000:]}\n{r.stderr[-2000:]}"
