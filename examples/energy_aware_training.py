"""Energy-aware training (the paper's core theme): train the same model under
different power caps, log the 1000 SPS telemetry, and report the
time/energy Pareto — reproducing the DVFS trade-off the DALEK platform was
built to measure (Sec. 3.6, 4, 6.1).

    PYTHONPATH=src python examples/energy_aware_training.py
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import energy
from repro.core.tracing import counting_jit
from repro.core.hw import TPU_V5E
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.train import loop as loop_mod
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import StepConfig, TrainState, make_train_step


def main():
    cfg = configs.get_smoke("zamba2-1.2b")
    model = build_model(cfg, q_block=16)
    # roofline terms for the smoke model running on one v5e (energy model
    # input; on a deployment these come from the dry-run records)
    terms = {"compute": 0.004, "memory": 0.003, "collective": 0.0}

    print("power-cap sweep (DVFS cubic model, paper Sec. 3.6):")
    print("cap_W  f_GHz  step_s  step_J  J_vs_uncapped")
    e0 = energy.step_energy_j(terms)
    for cap in (None, 180.0, 140.0, 100.0):
        st = energy.cap_frequency(cap, terms) if cap else None
        t = energy.step_time_s(terms, st)
        e = energy.step_energy_j(terms, st)
        f = st.f_ghz if st else TPU_V5E.f_max_ghz
        print(f"{cap or 'none':>5}  {f:.2f}  {t*1e3:6.2f}ms  {e:6.2f}J  "
          f"{e/e0:5.2f}x")

    # short real run with telemetry + tags
    params, _ = model.init(jax.random.key(0))
    state = TrainState(params, init_opt_state(params))
    step = counting_jit(make_train_step(model, OptConfig(lr=1e-3),
                                        StepConfig()),
                        "energy_example_train_step", donate_argnums=(0,))
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      global_batch=2), cfg)
    state, hist, summary = loop_mod.run(
        step, state, data, loop_mod.LoopConfig(total_steps=8),
        roofline_terms=terms)
    print(f"\n8 telemetered steps: {summary['tokens']} tokens, "
          f"{summary['energy_j']:.1f} J total at "
          f"{summary['avg_power_w']:.1f} W avg, "
          f"J/token={summary['j_per_token']:.4f}")
    print(f"per-tag attribution: "
          f"{ {k: round(v,1) for k,v in summary['energy_by_tag'].items()} }")


if __name__ == "__main__":
    main()
