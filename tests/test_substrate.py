"""Substrate tests: checkpointing (atomicity, async, restore), data pipeline
(determinism, prefetch), optimizer, HLO cost walker, train loop restart."""
import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   lr_schedule)


# ---------------------------------------------------------------------------
# checkpoint


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_ckpt_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, step=3, extra={"note": "x"})
    restored, manifest = ckpt.restore(tree, tmp_path)
    assert manifest["step"] == 3 and manifest["extra"]["note"] == "x"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, restored)


def test_ckpt_atomicity_partial_ignored(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, step=1)
    # fake a crashed save: directory without _COMMITTED
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ckpt.valid_steps(tmp_path) == [1]
    ckpt.gc_partial(tmp_path)
    assert not bad.exists()
    restored, manifest = ckpt.restore(tree, tmp_path)
    assert manifest["step"] == 1


def test_ckpt_async_and_prune(tmp_path):
    saver = ckpt.AsyncSaver()
    tree = _tree()
    for s in (1, 2, 3, 4):
        saver.save(tree, tmp_path, s)
    saver.wait()
    ckpt.prune(tmp_path, keep=2)
    assert ckpt.valid_steps(tmp_path) == [3, 4]


def test_ckpt_restore_picks_newest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    ckpt.save(tree, tmp_path, 1)
    ckpt.save({"x": jnp.ones((2,))}, tmp_path, 5)
    restored, m = ckpt.restore(tree, tmp_path)
    assert m["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(2))


# ---------------------------------------------------------------------------
# data pipeline


def test_data_deterministic_by_step():
    cfg = DataConfig(seed=3, vocab_size=100, seq_len=32, global_batch=4)
    d1, d2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
    for i in (0, 5, 17):
        np.testing.assert_array_equal(d1.batch(i)["tokens"],
                                      d2.batch(i)["tokens"])
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(seed=0, vocab_size=50, seq_len=16, global_batch=2)
    b = SyntheticTokens(cfg).batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_prefetcher_order_and_restart():
    cfg = DataConfig(seed=1, vocab_size=64, seq_len=8, global_batch=2)
    src = SyntheticTokens(cfg)
    pf = Prefetcher(src, start_step=10, prefetch=2)
    try:
        for want in (10, 11, 12):
            i, batch = pf.next()
            assert i == want
            np.testing.assert_array_equal(batch["tokens"],
                                          src.batch(want)["tokens"])
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# optimizer


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6            # peak at warmup end
    assert abs(lrs[-1] - 0.1) < 1e-3           # decays to min_lr_frac


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=400, weight_decay=0.0)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, metrics = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2
    assert metrics["grad_norm"] >= 0


def test_grad_clip_applied():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    g = {"w": jnp.full((4,), 100.0)}
    p2, state, metrics = adamw_update(cfg, params, g, state)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)
    assert np.isfinite(np.asarray(p2["w"])).all()


# ---------------------------------------------------------------------------
# HLO cost walker


def test_hlo_cost_counts_loops_exactly():
    from repro.perf.hlo_cost import analyze_text
    from jax import lax

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return lax.scan(body, x, w)[0]

    xs = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    comp = jax.jit(f).lower(xs, ws).compile()
    out = analyze_text(comp.as_text())
    assert out["flops"] == 7 * 2 * 64 * 32 * 32


def test_hlo_cost_nested_loops_multiply():
    from repro.perf.hlo_cost import analyze_text
    from jax import lax

    def f(x, w):
        def outer(c, _):
            def inner(ci, wi):
                return ci @ wi, None
            return lax.scan(inner, c, w)[0], None
        return lax.scan(outer, x, None, length=3)[0]

    xs = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    comp = jax.jit(f).lower(xs, ws).compile()
    out = analyze_text(comp.as_text())
    assert out["flops"] == 3 * 5 * 2 * 16 * 16 * 16


# ---------------------------------------------------------------------------
# train loop restart (fault-tolerance integration)


def test_train_loop_checkpoint_restart(tmp_path):
    from repro.train import loop as loop_mod

    calls = {"steps": []}

    def fake_step(state, batch):
        s = state["n"] + 1
        calls["steps"].append(int(s))
        return {"n": s}, {"loss": jnp.float32(1.0 / s), "grad_norm": jnp.float32(1.0)}

    data = SyntheticTokens(DataConfig(seed=0, vocab_size=16, seq_len=4,
                                      global_batch=2))
    cfg = loop_mod.LoopConfig(total_steps=6, ckpt_every=2,
                              ckpt_dir=str(tmp_path))
    state = {"n": jnp.int32(0)}
    state, hist, _ = loop_mod.run(fake_step, state, data, cfg)
    assert int(state["n"]) == 6
    # simulate crash + restart: resumes from step 6 checkpoint (no-op run)
    state2 = {"n": jnp.int32(0)}
    state2, hist2, _ = loop_mod.run(fake_step, state2, data, cfg)
    assert int(state2["n"]) == 6 and len(hist2) == 0
    # partial restart: delete newest, rerun -> resumes from 4
    shutil.rmtree(tmp_path / "step_00000006")
    state3, hist3, _ = loop_mod.run(fake_step, {"n": jnp.int32(0)}, data, cfg)
    assert len(hist3) == 2 and int(state3["n"]) == 6
