"""Energy platform tests: probe rates/resolution, main-board limits, GPIO
tags, DVFS/power-cap behaviour — each tied to a paper claim."""
import numpy as np
import pytest

from repro.core import energy, hw
from repro.core.mainboard import (BUS_MAX_SPS, MAX_PROBES, MainBoard,
                                  PROBES_PER_BUS)
from repro.core.probe import AVG_N, MILLIWATT, RAW_SPS, REPORT_SPS, Probe, ProbeConfig


def test_probe_rates_match_paper():
    # Sec. 4.2: 4000 SPS raw, averaged x4 -> 1000 reports/s
    assert RAW_SPS == 4000 and AVG_N == 4 and REPORT_SPS == 1000


def test_probe_sample_count_and_resolution():
    p = Probe(lambda t: 55.1234567, ProbeConfig(noise_w=0.0))
    samples = p.read(0.0, 0.25)
    assert len(samples) == 250                       # 1000 SPS
    for s in samples:
        assert s.n_avg == AVG_N
        # milliwatt quantization
        assert abs(s.watts / MILLIWATT - round(s.watts / MILLIWATT)) < 1e-6
        assert abs(s.watts - 55.123) < 0.001


def test_probe_beats_grid5000():
    """Paper Sec. 4.3: 1000 SPS @ 1 mW vs GRID'5000's ~50 SPS @ 0.1 W."""
    assert REPORT_SPS / 50 >= 20
    assert 0.1 / MILLIWATT >= 100


def test_probe_usb_pd_clamp():
    p = Probe(lambda t: 1000.0, ProbeConfig(noise_w=0.0))
    s = p.read(0.0, 0.01)
    assert all(abs(x.watts - 240.0) < 1e-6 for x in s)  # PD 3.1 limit


def test_mainboard_bus_limits():
    mb = MainBoard()
    for i in range(MAX_PROBES):
        mb.attach(Probe(lambda t: 10.0, ProbeConfig(probe_id=i)))
    assert mb.n_probes == MAX_PROBES
    with pytest.raises(RuntimeError):
        mb.attach(Probe(lambda t: 10.0), bus=0)
    assert mb.effective_sps(0) == BUS_MAX_SPS / PROBES_PER_BUS == REPORT_SPS


def test_gpio_tag_energy_attribution():
    mb = MainBoard()
    mb.attach(Probe(lambda t: 100.0, ProbeConfig(noise_w=0.0)))
    samples = []
    with mb.tags.tag("region_a"):
        samples += mb.read_samples(0.1)[0]
    samples += mb.read_samples(0.1)[0]   # untagged
    by_tag = MainBoard.energy_by_tag(samples)
    total = MainBoard.energy_j(samples)
    assert abs(by_tag["region_a"] - 10.0) < 0.2     # 100 W * 0.1 s
    assert abs(total - 20.0) < 0.4
    # 8-GPIO hardware limit
    with pytest.raises(RuntimeError):
        for i in range(9):
            mb.tags.raise_(f"t{i}")


def test_mainboard_columnar_read_matches_legacy():
    """`read_block` (the `repro.telemetry` hot path) and `read_samples`
    produce identical streams from identically seeded probes."""
    legacy, columnar = MainBoard(), MainBoard()
    for mb in (legacy, columnar):
        mb.attach(Probe(lambda t: 80.0 + 5 * np.sin(t), ProbeConfig()))
    with legacy.tags.tag("fwd"):
        samples = legacy.read_samples(0.1)[0]
    with columnar.tags.tag("fwd"):
        block = columnar.read_block(0.1)[0]
    assert block.n == len(samples) == 100
    assert np.array_equal(block.watts, [s.watts for s in samples])
    assert abs(MainBoard.energy_j(samples) - block.energy_j()) < 1e-9
    by_leg, by_col = MainBoard.energy_by_tag(samples), block.energy_by_tag()
    assert abs(by_leg["fwd"] - by_col["fwd"]) < 1e-9


def test_dvfs_cubic_power_monotone():
    dev = hw.TPU_V5E
    powers = [energy.power_w(dev, 1.0, energy.DvfsState(f))
              for f in np.linspace(dev.f_min_ghz, dev.f_max_ghz, 5)]
    assert all(a < b for a, b in zip(powers, powers[1:]))
    assert abs(powers[-1] - dev.tdp_w) < 1e-6


def test_power_cap_respected():
    dev = hw.TPU_V5E
    terms = {"compute": 1.0, "memory": 0.4, "collective": 0.2}
    cap = 150.0
    st = energy.cap_frequency(cap, terms, dev)
    t = energy.step_time_s(terms, st, dev)
    avg_w = energy.step_energy_j(terms, st, dev) / t
    assert avg_w <= cap + 1e-6
    # capping costs time
    assert t >= energy.step_time_s(terms, None, dev)


def test_pareto_energy_time_tradeoff():
    terms = {"compute": 1.0, "memory": 0.3, "collective": 0.1}
    front = energy.pareto_frontier(terms)
    times = [p["step_s"] for p in front]
    assert times[0] > times[-1]          # higher f -> faster


def test_cluster_idle_power_paper_claim():
    # Sec. 3.4: idle cluster (nodes off) ~50 W
    assert 40.0 <= hw.cluster_idle_w("off") <= 60.0
    # Tab. 2 totals
    idle = hw.cluster_idle_w("idle")
    assert abs(idle - hw.PAPER_TOTALS["idle_w"]) < 1.0


def test_paper_suspend_total():
    susp = sum(p.suspend_w for p in hw.DALEK_PARTITIONS.values())
    assert abs(susp - hw.PAPER_TOTALS["suspend_w"]) < 1.0


def test_paper_tdp_total():
    tdp = (sum(p.tdp_w for p in hw.DALEK_PARTITIONS.values())
           + hw.FRONTEND.tdp_w + hw.SWITCH_TDP_W + hw.N_RPI * hw.RPI_TDP_W)
    assert abs(tdp - hw.PAPER_TOTALS["tdp_w"]) < 1.0
