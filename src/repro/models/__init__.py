from repro.models.registry import abstract_params, build_model, token_batch_specs
