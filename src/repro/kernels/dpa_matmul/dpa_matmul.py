"""Dot-Product-Accumulate matmul Pallas kernel (paper Fig. 5/7, Sec. 5.2).

The paper benchmarks FMA (f32/f64), DPA2 (2-way bf16/i16 -> f32/i32) and
DPA4 (4-way i8 -> i32) — the CPU ancestors of the TPU MXU, which natively
performs bf16xbf16->f32 and int8xint8->int32 systolic dot-product-
accumulate. This kernel is the TPU-native adaptation: a VMEM-tiled matmul
with an fp32/int32 accumulator scratch, K-blocked so the working set fits
VMEM and the MXU dims stay 128-aligned.

Variants (mirroring the paper's instruction sweep):
    fma_f32:  f32 x f32 -> f32
    dpa2:     bf16 x bf16 -> f32 accumulate   (AVX-VNNI bf16 analogue)
    dpa4:     int8 x int8 -> int32 accumulate (AVX-VNNI i8 analogue)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_blocks, acc_dtype):
    """Grid (M/bm, N/bn, K/bk); accumulate over the K axis in scratch."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype)

    @pl.when(kb == k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dpa_matmul(a, b, *, variant="dpa2", block_m=128, block_n=128,
               block_k=256, interpret=False):
    """a: [M,K], b: [K,N] -> [M,N] in the accumulator dtype.

    variant: fma_f32 | dpa2 (bf16) | dpa4 (int8).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    if variant == "fma_f32":
        in_dtype, acc_dtype, out_dtype = jnp.float32, jnp.float32, jnp.float32
    elif variant == "dpa2":
        in_dtype, acc_dtype, out_dtype = jnp.bfloat16, jnp.float32, jnp.float32
    elif variant == "dpa4":
        in_dtype, acc_dtype, out_dtype = jnp.int8, jnp.int32, jnp.int32
    else:
        raise ValueError(variant)
    a = a.astype(in_dtype)
    b = b.astype(in_dtype)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_kernel, k_blocks=grid[2],
                               acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a, b)
