"""SLURM-like resource manager (paper Sec. 3.4/3.5) with the paper's planned
time+energy quotas (Sec. 6.2) implemented.

Semantics reproduced from the paper:
  - salloc/srun/sbatch -> ``submit``: powered-off nodes are woken (WoL),
    jobs start after boot (up to ~2 min);
  - nodes power off after 10 min idle;
  - login policy: access only while holding a reservation (``can_login``);
  - scratch per user, preserved across jobs;
  - MUNGE-style credentials are modeled as opaque tokens;
  - per-user time AND energy quotas, debited from telemetry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Callable, Dict, List, Optional

from repro.core.elastic import ElasticController, PowerState
from repro.cluster.topology import Topology
from repro.obs import MetricsRegistry


@dataclasses.dataclass
class Quota:
    time_s: float = float("inf")
    energy_j: float = float("inf")
    used_time_s: float = 0.0
    used_energy_j: float = 0.0

    def ok(self) -> bool:
        return (self.used_time_s < self.time_s
                and self.used_energy_j < self.energy_j)


@dataclasses.dataclass
class Job:
    job_id: int
    user: str
    partition: str
    n_nodes: int
    duration_s: float
    power_model: Optional[Callable[[str], float]] = None  # node -> watts
    nodes: List[str] = dataclasses.field(default_factory=list)
    state: str = "PENDING"          # PENDING | CONFIGURING | RUNNING | DONE | FAILED | CANCELLED
    submit_t: float = 0.0
    start_t: float = 0.0
    end_t: float = 0.0
    energy_j: float = 0.0


class ClusterManager:
    """Event-stepped scheduler + power manager over a Topology."""

    def __init__(self, topo: Topology, idle_off_s: float = 600.0,
                 metrics: Optional[MetricsRegistry] = None):
        self.topo = topo
        self.elastic = ElasticController(
            {n: node.spec for n, node in topo.nodes.items()},
            idle_off_s=idle_off_s)
        self.jobs: Dict[int, Job] = {}
        self.quotas: Dict[str, Quota] = {}
        self._ids = itertools.count(1)
        self._creds: Dict[str, str] = {}
        self.scratch: Dict[str, Dict[str, list]] = {}   # node -> user -> files
        # shared observability registry (jobs by state transition, per-user
        # quota energy, live cluster watts) — same store the engines use
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    # -- auth (MUNGE analogue) ------------------------------------------------

    def credential(self, user: str) -> str:
        tok = hashlib.sha256(f"{user}:{self.elastic.t}".encode()).hexdigest()[:16]
        self._creds[tok] = user
        return tok

    def validate(self, token: str) -> Optional[str]:
        return self._creds.get(token)

    # -- quotas (paper Sec. 6.2) ----------------------------------------------

    def set_quota(self, user: str, time_s=float("inf"), energy_j=float("inf")):
        self.quotas[user] = Quota(time_s, energy_j)

    def quota(self, user: str) -> Quota:
        return self.quotas.setdefault(user, Quota())

    # -- job lifecycle ----------------------------------------------------------

    def submit(self, user: str, partition: str, n_nodes: int,
               duration_s: float, power_model=None) -> Job:
        job = Job(next(self._ids), user, partition, n_nodes, duration_s,
                  power_model, submit_t=self.elastic.t)
        self.metrics.counter("cluster_jobs_submitted").inc(user=user)
        if not self.quota(user).ok():
            job.state = "FAILED"
            job.end_t = self.elastic.t
            self.jobs[job.job_id] = job
            self.metrics.counter("cluster_jobs_failed",
                                 "jobs rejected or failed").inc(
                reason="quota")
            return job
        free = [n for n in self.topo.partition_nodes(partition)
                if not self._node_busy(n)]
        if len(free) < n_nodes:
            job.state = "PENDING"
            self.jobs[job.job_id] = job
            return job
        job.nodes = free[:n_nodes]
        ready = self.elastic.resume(job.nodes)   # WoL if powered off
        job.state = "CONFIGURING" if ready > self.elastic.t else "RUNNING"
        job.start_t = ready
        job.end_t = ready + duration_s
        self.jobs[job.job_id] = job
        return job

    def _node_busy(self, name: str) -> bool:
        for j in self.jobs.values():
            if j.state in ("RUNNING", "CONFIGURING") and name in j.nodes:
                return True
        return False

    def cancel(self, job_id: int):
        job = self.jobs[job_id]
        if job.state in ("RUNNING", "CONFIGURING", "PENDING"):
            job.state = "CANCELLED"
            job.end_t = self.elastic.t
            if job.nodes:
                self.elastic.release(job.nodes)

    def advance(self, dt: float):
        """Advance simulation time; finish jobs; debit quotas; start pending."""
        target = self.elastic.t + dt
        while self.elastic.t < target:
            events = [target]
            for j in self.jobs.values():
                if j.state == "CONFIGURING":
                    events.append(j.start_t)
                if j.state in ("RUNNING", "CONFIGURING"):
                    events.append(j.end_t)
            t_next = min(e for e in events if e > self.elastic.t)
            step = t_next - self.elastic.t
            # accumulate job energy over the step
            for j in self.jobs.values():
                if j.state == "RUNNING":
                    for n in j.nodes:
                        w = (j.power_model(n) if j.power_model
                             else self.elastic.nodes[n].spec.tdp_w)
                        j.energy_j += w * step
            self.elastic.advance(step)
            for j in self.jobs.values():
                if j.state == "CONFIGURING" and self.elastic.t >= j.start_t:
                    j.state = "RUNNING"
                    self.elastic.mark_busy(j.nodes)
                if j.state == "RUNNING" and self.elastic.t >= j.end_t:
                    j.state = "DONE"
                    self.elastic.release(j.nodes)
                    q = self.quota(j.user)
                    q.used_time_s += j.end_t - j.start_t
                    q.used_energy_j += j.energy_j
                    self.metrics.counter("cluster_jobs_completed").inc(
                        user=j.user)
                    self.metrics.counter(
                        "cluster_job_energy_j",
                        "measured joules debited to user quotas").inc(
                        j.energy_j, user=j.user)
            self._start_pending()
        self.metrics.gauge("cluster_power_w",
                           "live whole-cluster draw").set(
            self.elastic.total_power_w())

    def _start_pending(self):
        for j in self.jobs.values():
            if j.state != "PENDING":
                continue
            if not self.quota(j.user).ok():
                j.state = "FAILED"
                self.metrics.counter("cluster_jobs_failed",
                                     "jobs rejected or failed").inc(
                    reason="quota")
                continue
            free = [n for n in self.topo.partition_nodes(j.partition)
                    if not self._node_busy(n)]
            if len(free) >= j.n_nodes:
                j.nodes = free[:j.n_nodes]
                ready = self.elastic.resume(j.nodes)
                j.start_t = max(ready, self.elastic.t)
                j.end_t = j.start_t + j.duration_s
                j.state = "CONFIGURING" if j.start_t > self.elastic.t else "RUNNING"
                if j.state == "RUNNING":
                    self.elastic.mark_busy(j.nodes)

    # -- login policy (SPANK/PAM analogue, paper Sec. 3.5) ---------------------

    def can_login(self, user: str, node: str) -> bool:
        for j in self.jobs.values():
            if (j.user == user and j.state == "RUNNING" and node in j.nodes):
                # scratch dir auto-created at first login
                self.scratch.setdefault(node, {}).setdefault(user, [])
                return True
        return False

    def cluster_power_w(self) -> float:
        return self.elastic.total_power_w()
