"""Logical-axis sharding: maps model-declared logical axes onto mesh axes.

The framework mirrors the paper's cluster structure: a fast intra-pod network
(the ``data``/``model`` mesh axes — ICI) and a slow inter-pod network (the
``pod`` axis — DALEK's 2.5 GbE analogue). Parameters are FSDP-sharded over
``data`` and tensor-parallel over ``model``; the ``pod`` axis only carries
data parallelism (gradient all-reduce, optionally compressed — see
``repro.parallel.compress``).

Every parameter and key activation declares *logical* axes (e.g.
``("layers", "embed", "heads", "head_dim")``); :func:`spec_for` resolves them
to a :class:`PartitionSpec` with divisibility checks, so the same model code
lowers on any mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> preferred mesh axis (None = replicate)
LOGICAL_RULES = {
    # parameter axes
    "layers": None,          # scan axis, never sharded
    "vocab": "model",        # TP over vocabulary (embed + unembed + logits)
    "embed": "data",         # FSDP: weight-shard d_model over the data axis
    "heads": "model",        # TP over attention heads
    "kv_heads": "model",     # TP over KV heads (dropped when indivisible: MQA)
    "head_dim": None,
    "mlp": "model",          # TP over FFN hidden
    "experts": "model",      # EP: experts over the model axis
    "expert_mlp": None,      # per-expert FFN hidden stays local
    "ssm_inner": "model",    # TP over SSM inner channels
    "ssm_state": None,
    "conv_width": None,
    "norm": None,
    # activation axes
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,          # overridden to "model" for seq-sharded caches
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_experts": "model",
    "act_vocab": "model",
    "act_mlp": "model",
    "qblock": None,
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def spec_for(
    mesh: Mesh,
    logical_axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    rules: Optional[dict] = None,
) -> P:
    """Resolve logical axes to a PartitionSpec.

    Mesh axes are dropped when (a) already used by an earlier dim or (b) the
    dim size is known and not divisible by the mesh axis size.
    """
    rules = {**LOGICAL_RULES, **(rules or {})}
    used = set()
    out = []
    for i, lax_name in enumerate(logical_axes):
        mesh_axis = rules.get(lax_name) if lax_name is not None else None
        if mesh_axis is None:
            out.append(None)
            continue
        flat = tuple(mesh_axis) if isinstance(mesh_axis, (tuple, list)) else (mesh_axis,)
        # only keep sub-axes present in this mesh, unused, and divisible
        keep = []
        for a in flat:
            if a not in mesh.shape or a in used:
                continue
            keep.append(a)
        if shape is not None:
            size = 1
            for a in keep:
                size *= mesh.shape[a]
            while keep and size > 0 and shape[i] % size != 0:
                dropped = keep.pop()
                size //= mesh.shape[dropped]
        if not keep:
            out.append(None)
        else:
            used.update(keep)
            out.append(tuple(keep) if len(keep) > 1 else keep[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


class Sharder:
    """Applies activation sharding constraints; no-op without a mesh.

    Model code calls ``shd(x, "batch", "seq", "act_heads", None)`` at layer
    boundaries; on a real mesh this pins the GSPMD propagation, on a single
    device (smoke tests) it is the identity.
    """

    def __init__(self, mesh: Optional[Mesh] = None, rules: Optional[dict] = None,
                 barrier: bool = False):
        self.mesh = mesh
        self.rules = rules
        # pin block-output dtype across the sharding boundary: stops XLA from
        # hoisting f32 converts above the TP all-reduce (halves its volume)
        self.barrier = barrier

    def spec(self, logical_axes, shape=None) -> P:
        assert self.mesh is not None
        return spec_for(self.mesh, logical_axes, shape, self.rules)

    def __call__(self, x, *logical_axes):
        if self.mesh is None or self.mesh.empty:
            return x
        spec = spec_for(self.mesh, logical_axes, x.shape, self.rules)
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )
        if self.barrier and "act_embed" in logical_axes:
            x = jax.lax.optimization_barrier(x)
        return x

    def named(self, spec: P):
        assert self.mesh is not None
        return NamedSharding(self.mesh, spec)


def tree_specs(mesh: Mesh, axes_tree, shape_tree=None, rules=None):
    """Map a pytree of logical-axis tuples (+ optional shapes) to PartitionSpecs."""
    if shape_tree is None:
        return jax.tree.map(
            lambda axes: spec_for(mesh, axes, None, rules),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x
            ),
        )
    return jax.tree.map(
        lambda axes, shp: spec_for(mesh, axes, shp, rules),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )
