"""Chrome-trace-event / Perfetto timeline export with energy attribution.

Turns a :class:`~repro.obs.spans.Tracer`'s span stream into the JSON object
format Perfetto (ui.perfetto.dev) and ``chrome://tracing`` load directly:
one ``X`` (complete) event per span, one timeline row per track ("engine"
plus one ``req<N>`` row per request), and a ``board_power_w`` counter
series derived from the ``MonitorSession`` energy windows.

Energy attribution is the point: a span whose ``window`` (or ``windows``)
attribute references session sample-window indices gets those windows'
joules as ``args.energy_j``. The engines reference every window from
exactly one step span, so the per-span joules **partition** the session
total — ``sum(span energy) == EnergyReport.energy_j`` exactly, the tested
acceptance bar — and Perfetto shows where every joule of a run went.

A recorded ``.dkt`` trace replays into the same timeline:
:func:`timeline_from_trace` rebuilds phase spans from the typed event log
(one event per recorded chunk, ``obs.events``) with energies read from the
recorded sample blocks, so live export and offline replay produce the same
document shape.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.events import coerce_event
from repro.obs.spans import SpanRecord, Tracer

__all__ = ["chrome_trace", "write_chrome_trace", "validate_chrome_trace",
           "parse_chrome_trace", "timeline_from_trace", "session_energies"]

_US = 1e6                    # trace-event timestamps are microseconds
PID = 1                      # one process per document


def session_energies(session) -> Tuple[List[float], List[float]]:
    """(energy_j, duration_s) per sample window of a ``MonitorSession``
    (index-aligned with the engine's typed event log)."""
    blocks = session.blocks()
    return ([b.energy_j() for b in blocks], [b.duration_s() for b in blocks])


def _span_windows(rec: SpanRecord) -> List[int]:
    w = rec.attrs.get("window")
    ws = rec.attrs.get("windows")
    out = []
    if w is not None and int(w) >= 0:
        out.append(int(w))
    if ws:
        out.extend(int(i) for i in ws)
    return out


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def chrome_trace(spans: Sequence[SpanRecord],
                 window_energies: Optional[Sequence[float]] = None,
                 window_walls: Optional[Sequence[float]] = None,
                 meta: Optional[Dict] = None,
                 n_dropped: int = 0) -> Dict:
    """Build the trace-event JSON document (pure function of its inputs).

    ``window_energies[i]`` is the joules of session sample window ``i``;
    spans referencing windows get the summed joules as ``args.energy_j``.
    A window referenced by more than one span raises — double-attributed
    joules would silently break the sum-to-total invariant.
    """
    energies = list(window_energies or [])
    walls = list(window_walls or [])
    events: List[Dict] = [{
        "name": "process_name", "ph": "M", "pid": PID, "tid": 0,
        "args": {"name": (meta or {}).get("process", "dalek")}}]

    tracks = []
    for r in spans:
        if r.track not in tracks:
            tracks.append(r.track)
    if "engine" in tracks:                      # engine row always on top
        tracks.remove("engine")
        tracks.insert(0, "engine")
    tids = {tr: i for i, tr in enumerate(tracks)}
    for tr, tid in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": PID,
                       "tid": tid, "args": {"name": tr}})

    claimed: Dict[int, int] = {}                # window -> span_id
    attributed = 0.0
    for r in sorted(spans, key=lambda r: (r.t0, r.span_id)):
        args = {"span_id": r.span_id, "parent_id": r.parent_id}
        args.update({k: _jsonable(v) for k, v in r.attrs.items()})
        wins = _span_windows(r)
        e_j = 0.0
        for w in wins:
            if w in claimed:
                raise ValueError(
                    f"window {w} referenced by spans {claimed[w]} and "
                    f"{r.span_id}: joules would be attributed twice")
            claimed[w] = r.span_id
            if w < len(energies):
                e_j += energies[w]
        if wins:
            args["energy_j"] = e_j
            attributed += e_j
        base = {"name": r.name, "cat": r.track, "pid": PID,
                "tid": tids[r.track], "ts": r.t0 * _US, "args": args}
        if r.t1 > r.t0:
            events.append({**base, "ph": "X",
                           "dur": (r.t1 - r.t0) * _US})
        else:
            events.append({**base, "ph": "i", "s": "t"})
        # power counter series: one point per referenced window, at the
        # span's start, so the Perfetto counter row tracks the span rows
        for w in wins:
            if w < len(energies):
                wall = (walls[w] if w < len(walls) and walls[w] > 0
                        else max(r.t1 - r.t0, 1e-9))
                events.append({
                    "name": "board_power_w", "ph": "C", "pid": PID,
                    "tid": tids[r.track], "ts": r.t0 * _US,
                    "args": {"W": energies[w] / wall}})

    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "energy_total_j": float(sum(energies)),
            "attributed_j": float(attributed),
            "n_windows": len(energies),
            "n_spans": len(spans),
            "dropped_spans": int(n_dropped),
            **{k: _jsonable(v) for k, v in (meta or {}).items()},
        },
    }
    validate_chrome_trace(doc)
    return doc


def write_chrome_trace(path, tracer_or_spans, session=None,
                       window_energies: Optional[Sequence[float]] = None,
                       window_walls: Optional[Sequence[float]] = None,
                       meta: Optional[Dict] = None) -> str:
    """Validate and write a timeline JSON. Pass the live ``session`` (its
    sample windows supply the energies) or explicit per-window joules."""
    if isinstance(tracer_or_spans, Tracer):
        spans = tracer_or_spans.spans()
        n_dropped = tracer_or_spans.n_dropped
    else:
        spans, n_dropped = list(tracer_or_spans), 0
    if session is not None:
        if window_energies is not None:
            raise ValueError("pass session or window_energies, not both")
        window_energies, window_walls = session_energies(session)
    doc = chrome_trace(spans, window_energies, window_walls, meta=meta,
                       n_dropped=n_dropped)
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, separators=(",", ":"))
    return str(path)


# ---------------------------------------------------------------------------
# validation + parse-back


_PH_KNOWN = {"X", "B", "E", "i", "C", "M"}


def validate_chrome_trace(doc) -> None:
    """Schema check (raises ``ValueError``): the subset of the trace-event
    format the exporter emits, strict enough that Perfetto will load any
    document that passes."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a trace document: missing traceEvents")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("traceEvents must be a list")
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"traceEvents[{i}] missing {k!r}")
        ph = ev["ph"]
        if ph not in _PH_KNOWN:
            raise ValueError(f"traceEvents[{i}]: unknown phase {ph!r}")
        if ph in ("X", "i", "C", "B", "E") and "ts" not in ev:
            raise ValueError(f"traceEvents[{i}]: {ph} event missing ts")
        if ph == "X":
            if "dur" not in ev or float(ev["dur"]) < 0:
                raise ValueError(
                    f"traceEvents[{i}]: X event needs non-negative dur")
        if ph == "C" and "args" not in ev:
            raise ValueError(f"traceEvents[{i}]: counter missing args")
    od = doc.get("otherData", {})
    for k in ("energy_total_j", "attributed_j"):
        if k in od and not isinstance(od[k], (int, float)):
            raise ValueError(f"otherData.{k} must be numeric")


def parse_chrome_trace(doc_or_path) -> Tuple[List[SpanRecord], Dict]:
    """Parse a written timeline back into span records + a summary.

    Round-trip contract (tested): span ids, parentage, tracks, times
    (to trace-event microsecond resolution), attributes, and per-span
    ``energy_j`` all survive; ``summary['attributed_j']`` equals the sum
    of the parsed per-span energies.
    """
    if isinstance(doc_or_path, (str, bytes)) or hasattr(doc_or_path,
                                                        "__fspath__"):
        with open(doc_or_path) as f:
            doc = json.load(f)
    else:
        doc = doc_or_path
    validate_chrome_trace(doc)
    tracks: Dict[int, str] = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "M" and ev["name"] == "thread_name":
            tracks[ev["tid"]] = ev["args"]["name"]
    records: List[SpanRecord] = []
    parsed_j = 0.0
    for ev in doc["traceEvents"]:
        if ev["ph"] not in ("X", "i") or "args" not in ev:
            continue
        args = dict(ev["args"])
        sid = args.pop("span_id", None)
        if sid is None:
            continue
        parent = args.pop("parent_id", None)
        t0 = ev["ts"] / _US
        t1 = t0 + ev.get("dur", 0.0) / _US
        parsed_j += args.get("energy_j", 0.0) if "window" in args \
            or "windows" in args else 0.0
        records.append(SpanRecord(
            span_id=int(sid), parent_id=None if parent is None
            else int(parent), name=ev["name"],
            track=tracks.get(ev["tid"], str(ev["tid"])), t0=t0, t1=t1,
            attrs=args))
    records.sort(key=lambda r: (r.t0, r.span_id))
    summary = dict(doc.get("otherData", {}))
    summary["parsed_attributed_j"] = parsed_j
    return records, summary


# ---------------------------------------------------------------------------
# replay: a recorded .dkt trace into the same timeline


def timeline_from_trace(reader, stream_id: Optional[int] = None,
                        meta: Optional[Dict] = None) -> Dict:
    """Rebuild the timeline of a recorded serving run (``record_engine``).

    One phase span per typed telemetry event, placed at the recorded
    session cursor, with that event's window energy read from the recorded
    sample chunk — chunk ``k`` of the stream *is* session window ``k``
    (the ``TelemetryEvent.window`` invariant), so the replayed timeline
    carries exactly the joules the live run measured.
    """
    events = [coerce_event(e) for e in reader.meta.get("events", [])]
    if not events:
        raise ValueError(
            f"{reader.path} has no telemetry event log — record the run "
            f"with tracestore.recorder.record_engine")
    sid = stream_id if stream_id is not None else reader.stream_ids()[0]
    blocks = list(reader.blocks(sid))
    energies = [b.energy_j() for b in blocks]
    walls = [b.duration_s() for b in blocks]
    spans: List[SpanRecord] = []
    cursor = 0.0
    for i, ev in enumerate(events):
        w = ev.window if ev.window >= 0 else i
        t0 = ev.t0 if ev.t0 > 0 or i == 0 else cursor
        attrs = {"window": w, "n_tokens": ev.n_tokens,
                 "requests": sorted({rid for ids in ev.groups.values()
                                     for rid in ids})}
        attrs.update(ev.extra)
        spans.append(SpanRecord(span_id=i, parent_id=None, name=ev.phase,
                                track="engine", t0=t0, t1=t0 + ev.wall_s,
                                attrs=attrs))
        cursor = t0 + ev.wall_s
    m = {"process": "dalek-replay", "trace_path": str(reader.path)}
    m.update(meta or {})
    return chrome_trace(spans, energies, walls, meta=m)
