"""Sharded, atomic checkpointing (pure JAX + numpy, no orbax).

Layout: <dir>/step_<n>/
    manifest.json            tree structure, shapes, dtypes, step metadata
    <leaf-path>.npy          one file per leaf (host-gathered)
    _COMMITTED               atomicity marker (written last)

Fault-tolerance contract: a checkpoint is valid iff _COMMITTED exists;
restore picks the newest valid step; partial writes from a crashed save are
ignored and garbage-collected. Saves can run in a background thread
(async_save) so the train loop overlaps I/O with compute — the paper's SSD
benchmarks (Fig. 9) motivate sizing this I/O.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"idx{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(tree, directory, step: int, extra: Optional[Dict] = None) -> pathlib.Path:
    """Atomic synchronous save. Returns the committed directory."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "leaves": {}}
    for key, leaf in _flatten(tree):
        arr = np.asarray(jax.device_get(leaf))
        # raw-byte serialization: preserves ml_dtypes (bfloat16, fp8, ...)
        (tmp / f"{key}.bin").write_bytes(arr.tobytes())
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "_COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncSaver:
    """Background-thread checkpointing; at most one save in flight."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[pathlib.Path] = None
        self.error: Optional[BaseException] = None

    def save(self, tree, directory, step, extra=None):
        self.wait()
        # device_get on the caller thread (arrays may be donated afterwards)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                self.last_path = save(host_tree, directory, step, extra)
            except BaseException as e:  # noqa
                self.error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            raise self.error


def valid_steps(directory) -> List[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    out = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / "_COMMITTED").exists():
            out.append(int(d.name.split("_")[1]))
    return sorted(out)


def gc_partial(directory):
    """Remove uncommitted (crashed) checkpoint attempts."""
    directory = pathlib.Path(directory)
    if not directory.exists():
        return
    for d in directory.iterdir():
        if d.name.startswith(".tmp_step_") or (
                d.name.startswith("step_") and not (d / "_COMMITTED").exists()):
            shutil.rmtree(d)


def restore(tree_like, directory, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like`` (SDS or arrays).

    shardings: optional matching tree of NamedSharding — leaves are placed
    sharded via jax.device_put (each host reads the full array; on a real
    multi-host deployment this becomes per-shard reads).
    """
    directory = pathlib.Path(directory)
    steps = valid_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    step = step if step is not None else steps[-1]
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    import ml_dtypes  # noqa: F401 (registers bfloat16 etc. with numpy)

    flat = _flatten(tree_like)
    shard_flat = _flatten(shardings)[0:] if shardings is not None else None
    out_leaves = []
    for i, (key, like) in enumerate(flat):
        meta = manifest["leaves"][key]
        dtype = np.dtype(getattr(ml_dtypes, meta["dtype"], None)
                         or meta["dtype"])
        raw = (d / f"{key}.bin").read_bytes()
        arr = np.frombuffer(raw, dtype=dtype).reshape(meta["shape"])
        if shardings is not None:
            arr = jax.device_put(arr, shard_flat[i][1])
        out_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


def prune(directory, keep: int = 3):
    directory = pathlib.Path(directory)
    steps = valid_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(directory / f"step_{s:08d}")
