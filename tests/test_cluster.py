"""Cluster-layer tests: elasticity (paper Sec. 3.4), SLURM-like manager,
quotas (Sec. 6.2), heterogeneous scheduling (Sec. 6.1), topology (Sec. 2),
fault tolerance + elastic restart."""
import numpy as np
import pytest

from repro.cluster.fault import (ElasticTrainOrchestrator, FailureInjector,
                                 HeartbeatMonitor)
from repro.cluster.manager import ClusterManager
from repro.cluster.topology import dalek_topology, tpu_topology, validate_addressing
from repro.core import hw
from repro.core.elastic import IDLE_OFF_S, ElasticController, PowerState
from repro.core.scheduler import (HeterogeneousScheduler, ResourceClass,
                                  StragglerMitigator, Task, WorkerStats,
                                  proportional_split)


def _nodes(n=4):
    part = hw.DALEK_PARTITIONS["az4-n4090"]
    return {f"n{i}": part.node for i in range(n)}


# ---------------------------------------------------------------------------
# elasticity


def test_idle_timeout_powers_off():
    ec = ElasticController(_nodes(2))
    ec.resume(["n0", "n1"])
    ec.advance(130.0)                       # boot (120s) + idle begins
    assert ec.nodes["n0"].state == PowerState.IDLE
    ec.advance(IDLE_OFF_S + 1)
    assert ec.nodes["n0"].state == PowerState.OFF
    assert ec.total_power_w() == 0.0


def test_boot_latency_within_paper_bound():
    ec = ElasticController(_nodes(1))
    ready = ec.resume(["n0"])
    assert ready - ec.t <= 120.0            # paper: up to 2 min


def test_busy_nodes_never_time_out():
    ec = ElasticController(_nodes(1))
    ec.resume(["n0"])
    ec.advance(125.0)
    ec.mark_busy(["n0"])
    ec.advance(IDLE_OFF_S * 3)
    assert ec.nodes["n0"].state == PowerState.BUSY


def test_energy_integration():
    ec = ElasticController(_nodes(1), idle_off_s=1e9)
    ec.resume(["n0"])
    ec.advance(120.0)                       # booting at idle power
    e_boot = ec.total_energy_j()
    assert abs(e_boot - 120.0 * 53.0) < 1.0
    ec.mark_busy(["n0"])
    ec.advance(10.0)
    assert abs(ec.total_energy_j() - e_boot - 10 * 525.0) < 1.0


# ---------------------------------------------------------------------------
# manager


def test_job_lifecycle_with_wol():
    cm = ClusterManager(dalek_topology())
    job = cm.submit("alice", "az4-n4090", 2, duration_s=100.0)
    assert job.state == "CONFIGURING"       # nodes were off -> booting
    cm.advance(125.0)
    assert cm.jobs[job.job_id].state == "RUNNING"
    assert cm.can_login("alice", job.nodes[0])
    assert not cm.can_login("bob", job.nodes[0])
    cm.advance(100.0)
    assert cm.jobs[job.job_id].state == "DONE"
    assert not cm.can_login("alice", job.nodes[0])
    # scratch survives job end (paper Sec. 3.5)
    assert "alice" in cm.scratch[job.nodes[0]]


def test_pending_when_partition_full():
    cm = ClusterManager(dalek_topology())
    j1 = cm.submit("a", "az4-a7900", 4, 50.0)
    j2 = cm.submit("b", "az4-a7900", 2, 50.0)
    assert j2.state == "PENDING"
    cm.advance(300.0)                       # j1 boots+runs+finishes
    assert cm.jobs[j2.job_id].state in ("RUNNING", "CONFIGURING", "DONE")


def test_energy_quota_enforced():
    cm = ClusterManager(dalek_topology())
    cm.set_quota("carol", energy_j=1.0)     # 1 J: exhausted by any job
    j1 = cm.submit("carol", "az5-a890m", 1, 10.0)
    cm.advance(200.0)
    assert cm.jobs[j1.job_id].state == "DONE"
    assert not cm.quota("carol").ok()
    j2 = cm.submit("carol", "az5-a890m", 1, 10.0)
    assert j2.state == "FAILED"


def test_idle_cluster_power_near_50w():
    cm = ClusterManager(dalek_topology())
    # all compute nodes start OFF: the manager adds nothing; frontend etc.
    # are outside compute management — paper's ~50 W claim
    assert cm.cluster_power_w() == 0.0
    assert 40 <= hw.cluster_idle_w("off") <= 60


def test_munge_credentials():
    cm = ClusterManager(dalek_topology())
    tok = cm.credential("dave")
    assert cm.validate(tok) == "dave"
    assert cm.validate("bogus") is None


# ---------------------------------------------------------------------------
# heterogeneous scheduling (Sec. 6.1)


def _classes():
    return [
        ResourceClass("p-cores", hw.RYZEN_7945HX, 4, efficiency=0.8),
        ResourceClass("e-cores", hw.RYZEN_AI_HX370, 8, efficiency=0.7),
    ]


def test_chain_scheduling_objectives_differ():
    tasks = [Task(f"t{i}", flops=1e12, deps=(f"t{i-1}",) if i else ())
             for i in range(6)]
    st, time_stats = HeterogeneousScheduler(_classes(), "time").schedule(tasks)
    se, energy_stats = HeterogeneousScheduler(_classes(), "energy").schedule(tasks)
    assert time_stats["makespan_s"] <= energy_stats["makespan_s"] + 1e-9
    assert energy_stats["energy_j"] <= time_stats["energy_j"] + 1e-9


def test_parallel_tasks_use_both_classes():
    tasks = [Task(f"p{i}", flops=1e12) for i in range(8)]
    placements, _ = HeterogeneousScheduler(_classes(), "time").schedule(tasks)
    used = {p.resource for p in placements}
    assert used == {"p-cores", "e-cores"}


def test_proportional_split_properties():
    workers = [WorkerStats("fast", 100.0), WorkerStats("slow", 25.0)]
    split = proportional_split(1000, workers)
    assert sum(split.values()) == 1000
    assert split["fast"] == 800 and split["slow"] == 200


def test_straggler_mitigation_rebalances():
    sm = StragglerMitigator(["a", "b"], threshold=0.05)
    for _ in range(5):
        sm.observe("a", 100, 1.0)
        sm.observe("b", 100, 4.0)           # b is 4x slower
    assert sm.should_resplit({"a": 500, "b": 500})
    split = sm.current_split(1000)
    assert split["a"] == 800 and split["b"] == 200
    # critical path improves ~1.6x
    t_before = 500 / 25.0
    t_after = max(split["a"] / 100.0, split["b"] / 25.0)
    assert t_before / t_after > 1.5


# ---------------------------------------------------------------------------
# topology (Sec. 2)


def test_dalek_topology_matches_paper():
    topo = dalek_topology()
    assert len(topo.nodes) == 16             # 4 partitions x 4 nodes
    assert validate_addressing(topo)
    assert topo.nodes["iml-ia770-0"].spec.net_gbps == 5.0
    assert topo.nodes["az4-n4090-0"].spec.net_gbps == 2.5
    assert topo.nodes["az4-n4090-0"].ip == "192.168.1.1"
    assert topo.nodes["iml-ia770-0"].ip == "192.168.1.65"


def test_bisection_slow_network():
    topo = dalek_topology()
    part = topo.partition_nodes("az4-n4090")
    # 4 nodes x 2.5 GbE = 10 Gbps max in/out of a partition: the paper's
    # "network saturates quickly" lesson
    assert topo.bisection_gbps(part) == 10.0


# ---------------------------------------------------------------------------
# fault tolerance


def test_heartbeat_detection():
    hb = HeartbeatMonitor(interval_s=1.0, miss_limit=3)
    hb.beat("n0", 0.0)
    hb.beat("n1", 0.0)
    hb.beat("n0", 5.0)
    assert hb.dead(6.0) == ["n1"]


def test_failure_injection_deterministic():
    fi = FailureInjector(mtbf_s=1000.0, seed=7)
    e1 = fi.schedule(["a", "b"], 5000.0)
    e2 = FailureInjector(mtbf_s=1000.0, seed=7).schedule(["a", "b"], 5000.0)
    assert e1 == e2 and len(e1) > 0


def test_elastic_orchestrator_survives_failures():
    calls = {"build": 0, "saves": []}

    def build(n):
        calls["build"] += 1
        return {"workers": n}

    def restore(sess, step):
        return step or 0

    def train_chunk(sess, start, n):
        return start + n

    def save(sess, step):
        calls["saves"].append(step)

    orch = ElasticTrainOrchestrator(
        build=build, restore=restore, train_chunk=train_chunk, save=save,
        ckpt_every=10, min_workers=2)
    st = orch.run(total_steps=100, initial_workers=4,
                  failure_events=[(15.0, 1), (47.0, 2)], step_time_s=1.0)
    assert st.step == 100
    assert st.restarts == 2
    assert st.n_workers == 2
    assert calls["build"] == 3               # initial + 2 shrinks
    assert st.lost_steps > 0                 # work was lost and redone
    assert calls["saves"][-1] == 100
