"""Typed telemetry event schema.

One event per engine sampling window: the record the replay harness
(``tracestore.replay.replay_attribution``) re-drives against recorded
power, and the record the timeline exporter (``obs.export``) merges with
span streams. Before this schema the engines logged raw dicts and every
consumer re-invented the key names; now ``EngineTelemetry``, the trace
store, and the exporter share one format.

``window`` is the event's index into the session's sample-block list: the
k-th event describes the k-th ``MonitorSession`` window, which is also the
k-th recorded chunk of a ``.dkt`` stream exported by ``record_engine`` —
the invariant that lets spans reference windows by index and lets a
recorded trace replay into the same timeline as the live run.

Serialized form is a flat JSON dict (``as_dict``) identical to the legacy
ad-hoc event log, so traces recorded before the schema existed load
unchanged (``from_dict`` treats unknown keys as ``extra``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Mapping, Optional, Tuple

_KNOWN = ("phase", "wall_s", "n_tokens", "groups", "window", "t0")


@dataclasses.dataclass(frozen=True)
class TelemetryEvent:
    """One sampling window of an engine run.

    ``groups`` maps each GPIO slot tag raised for the window to the request
    ids sharing it (the tag-bus attribution input). ``extra`` carries
    optional per-window annotations (e.g. ``cached_tokens`` on a
    prefix-cache hit) that ride into the trace meta and the span timeline.
    """

    phase: str                                # "prefill" | "decode" | ...
    wall_s: float
    n_tokens: int                             # computed tokens this window
    groups: Mapping[str, Tuple[int, ...]]     # slot tag -> request ids
    window: int = -1                          # session sample-block index
    t0: float = 0.0                           # session cursor at window start
    extra: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict:
        """Flat JSON-serializable form (legacy-log compatible)."""
        out: Dict = {"phase": self.phase, "wall_s": self.wall_s,
                     "n_tokens": self.n_tokens,
                     "groups": {tg: list(ids)
                                for tg, ids in self.groups.items()},
                     "window": self.window, "t0": self.t0}
        out.update(self.extra)
        return out

    @classmethod
    def from_dict(cls, d: Mapping) -> "TelemetryEvent":
        """Parse an event dict — new flat form or a pre-schema legacy log
        entry (no ``window``/``t0``; any other keys become ``extra``)."""
        extra = {k: v for k, v in d.items() if k not in _KNOWN}
        return cls(phase=d["phase"], wall_s=float(d["wall_s"]),
                   n_tokens=int(d.get("n_tokens", 0)),
                   groups={tg: tuple(ids)
                           for tg, ids in d.get("groups", {}).items()},
                   window=int(d.get("window", -1)),
                   t0=float(d.get("t0", 0.0)), extra=extra)

    # -- mapping-style access (legacy consumers indexed raw dicts) -----------

    def __getitem__(self, key: str):
        d = self.as_dict()
        return d[key]

    def get(self, key: str, default=None):
        return self.as_dict().get(key, default)

    def __contains__(self, key: str) -> bool:
        return key in self.as_dict()

    def keys(self) -> Iterator[str]:
        return iter(self.as_dict())


def coerce_event(e) -> TelemetryEvent:
    """Accept either a :class:`TelemetryEvent` or a raw event dict."""
    return e if isinstance(e, TelemetryEvent) else TelemetryEvent.from_dict(e)


def events_to_meta(events) -> list:
    """Serialize an event log for a trace file's JSON meta footer."""
    return [coerce_event(e).as_dict() for e in (events or [])]


def events_from_meta(rows) -> list:
    """Parse a trace meta event log back into typed events."""
    return [coerce_event(r) for r in (rows or [])]


def window_of(e) -> Optional[int]:
    """Window index of an event, None when the event predates the schema."""
    w = coerce_event(e).window
    return w if w >= 0 else None
