"""Energy model: J/step and J/token from the compiled dry-run + DVFS.

The paper measures socket power at 1000 SPS; on the TPU target we *derive*
power from the compiled artifact instead: the roofline terms give per-chip
busy time and utilization, the DVFS model gives power at a frequency, and
the probe/mainboard pipeline replays the resulting trace so every
paper experiment (tagging, averaging, capping) runs identically.

DVFS model (standard cubic): P(f, u) = P_idle + (P_tdp - P_idle) * u * (f/f_max)^3
with throughput proportional to f for compute-bound work and ~flat for
memory-bound work (memory clock is not scaled).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.core.hw import DeviceSpec, TPU_V5E


@dataclasses.dataclass(frozen=True)
class DvfsState:
    f_ghz: float

    def rel(self, dev: DeviceSpec) -> float:
        return self.f_ghz / dev.f_max_ghz


def power_w(dev: DeviceSpec, util: float, dvfs: Optional[DvfsState] = None) -> float:
    """Instantaneous device power at utilization ``util`` in [0,1]."""
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    return dev.idle_w + (dev.tdp_w - dev.idle_w) * util * rel ** 3


def step_time_s(roofline_terms: Dict[str, float],
                dvfs: Optional[DvfsState] = None,
                dev: DeviceSpec = TPU_V5E,
                overlap: float = 1.0) -> float:
    """Predicted step time from the three roofline terms.

    overlap=1.0: perfect compute/comm overlap (max of terms);
    overlap=0.0: fully serialized (sum of terms).
    Compute scales 1/f; memory and collective terms do not.
    """
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    c = roofline_terms["compute"] / max(rel, 1e-6)
    m = roofline_terms["memory"]
    x = roofline_terms["collective"]
    t_overlap = max(c, m, x)
    t_serial = c + m + x
    return overlap * t_overlap + (1.0 - overlap) * t_serial


def step_energy_j(roofline_terms: Dict[str, float],
                  dvfs: Optional[DvfsState] = None,
                  dev: DeviceSpec = TPU_V5E,
                  overlap: float = 1.0) -> float:
    """Per-chip energy of one step: P(util, f) * t_step."""
    t = step_time_s(roofline_terms, dvfs, dev, overlap)
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    busy = roofline_terms["compute"] / max(rel, 1e-6)
    util = min(busy / t, 1.0) if t > 0 else 0.0
    return power_w(dev, util, dvfs) * t


def tokens_per_joule(roofline_terms, tokens_per_step, n_chips,
                     dvfs=None, dev=TPU_V5E) -> float:
    e = step_energy_j(roofline_terms, dvfs, dev) * n_chips
    return tokens_per_step / e if e else 0.0


def power_trace_fn(roofline_terms, dvfs=None, dev: DeviceSpec = TPU_V5E,
                   period_s: Optional[float] = None) -> Callable[[float], float]:
    """power(t) for one chip running repeated steps — drives the probes.

    Within each step the trace is piecewise: compute-bound phase at high
    power, then memory/collective-bound phase at lower power (utilization
    drops while waiting on HBM/ICI).
    """
    t_step = period_s or step_time_s(roofline_terms, dvfs, dev)
    rel = 1.0 if dvfs is None else dvfs.rel(dev)
    t_busy = min(roofline_terms["compute"] / max(rel, 1e-6), t_step)

    def fn(t: float) -> float:
        phase = t % t_step
        util = 1.0 if phase < t_busy else 0.35  # stall power fraction
        return power_w(dev, util, dvfs)

    return fn


# ---------------------------------------------------------------------------
# power capping (paper Sec. 3.6: RAPL / nvidia-smi power caps)


def cap_frequency(cap_w: float, roofline_terms, dev: DeviceSpec = TPU_V5E,
                  n_steps: int = 32) -> DvfsState:
    """Highest frequency whose average step power is within the cap.

    Discrete frequency ladder (like cpufreq governors); returns f_min even
    if the cap is unreachable (can't go below idle).
    """
    for i in range(n_steps, -1, -1):
        f = dev.f_min_ghz + (dev.f_max_ghz - dev.f_min_ghz) * i / n_steps
        st = DvfsState(f)
        t = step_time_s(roofline_terms, st, dev)
        e = step_energy_j(roofline_terms, st, dev)
        if t > 0 and e / t <= cap_w:
            return st
    return DvfsState(dev.f_min_ghz)


def pareto_frontier(roofline_terms, dev: DeviceSpec = TPU_V5E, n: int = 16):
    """(f, time, energy) sweep — the energy/performance trade-off the paper's
    DVFS + measurement platform is built to explore."""
    out = []
    for i in range(n + 1):
        f = dev.f_min_ghz + (dev.f_max_ghz - dev.f_min_ghz) * i / n
        st = DvfsState(f)
        out.append({
            "f_ghz": f,
            "step_s": step_time_s(roofline_terms, st, dev),
            "step_j": step_energy_j(roofline_terms, st, dev),
        })
    return out
