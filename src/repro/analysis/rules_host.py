"""DLK002 host-sync-in-hot-loop.

The engines are designed around *one* host sync per step (the [B,1]
token fetch). Any extra ``np.asarray``/``.item()``/``int()``/``float()``
on a device value inside the step loop serializes host and device and,
per PAPER.md, burns idle watts while the accelerator drains. The rule
taints results of jit-wrapped calls, propagates the taint through plain
assignments, and flags sync calls on tainted values inside a loop.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.core import (Finding, ModuleContext, Rule, qualname,
                                 register, root_name)

#: ``f(x)`` forms that copy a device value to host
SYNC_QUALNAMES = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                  "jax.device_get", "onp.asarray"}
#: ``x.m()`` forms that block on the device
SYNC_METHODS = {"item", "tolist", "block_until_ready"}
#: builtins that concretize a scalar
SYNC_BUILTINS = {"int", "float", "bool"}


def _sync_call(node: ast.Call, ctx: ModuleContext):
    """(kind, synced-expression) if this call is a host sync, else None."""
    qn = qualname(node.func)
    if qn in SYNC_QUALNAMES and node.args:
        return qn, node.args[0]
    if isinstance(node.func, ast.Attribute) and node.func.attr in SYNC_METHODS:
        return f".{node.func.attr}()", node.func.value
    if isinstance(node.func, ast.Name) and node.func.id in SYNC_BUILTINS \
            and len(node.args) == 1:
        return f"{node.func.id}()", node.args[0]
    return None


def _device_taint(fn: ast.FunctionDef, ctx: ModuleContext) -> Set[str]:
    """Names in ``fn`` holding device values: results of calls to
    jit-wrapped names, propagated through assignments. Assigning a sync
    result *clears* the taint (the copy lives on host)."""
    jitted = ctx.jitted_names
    tainted: Set[str] = set()

    def value_tainted(expr) -> bool:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call):
                f = sub.func
                if isinstance(f, ast.Name) and f.id in jitted:
                    return True
                if isinstance(f, ast.Attribute) and f.attr in jitted:
                    return True
            elif isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                    and sub.id in tainted:
                return True
        return False

    # two passes: taint introduced late in a loop body flows to syncs
    # earlier in the same body on the next iteration
    for _ in range(2):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            is_sync = isinstance(node.value, ast.Call) \
                and _sync_call(node.value, ctx) is not None
            hot = value_tainted(node.value) and not is_sync
            for tgt in node.targets:
                for t in (tgt.elts if isinstance(tgt, ast.Tuple)
                          else [tgt]):
                    if isinstance(t, ast.Name):
                        (tainted.add if hot else tainted.discard)(t.id)
    return tainted


@register
class HostSyncInHotLoop(Rule):
    """Host sync on a device value inside a loop of a function that drives
    jitted steps. Each one stalls the dispatch queue; the engines budget
    exactly one per decode step."""

    code = "DLK002"
    name = "host-sync"
    skip_tests = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn in ctx.functions:
            if not ctx.calls_jitted(fn):
                continue
            tainted = _device_taint(fn, ctx)
            if not tainted:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                sync = _sync_call(node, ctx)
                if sync is None:
                    continue
                loop = ctx.enclosing(node, (ast.For, ast.While))
                if loop is None or ctx.enclosing_function(loop) is not fn:
                    continue
                kind, expr = sync
                root = root_name(expr)
                roots = {root} if root else {
                    n.id for n in ast.walk(expr)
                    if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
                hits = roots & tainted
                if hits:
                    yield ctx.finding(
                        self, node,
                        f"host sync {kind} on device value "
                        f"'{sorted(hits)[0]}' in the hot loop of "
                        f"'{fn.name}' — stalls the dispatch queue every "
                        "iteration")
