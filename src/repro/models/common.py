"""Shared model components: params, norms, RoPE, GQA attention, MLPs, loss.

All modules are pure functions over explicit parameter pytrees. Every init
function returns ``(params, axes)`` where ``axes`` mirrors the params tree
with logical-axis tuples consumed by ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Sharder

# ---------------------------------------------------------------------------
# parameter helpers


def _init(key, shape, dtype, scale):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


class ParamBuilder:
    """Accumulates (params, logical-axes) trees with auto key splitting.

    Pass ``key=None`` for *abstract* mode: parameters become
    ShapeDtypeStructs (no allocation, no RNG) — used by the dry-run.
    """

    def __init__(self, key, dtype=jnp.float32):
        self.key = key
        self.dtype = dtype
        self.params = {}
        self.axes = {}

    @property
    def abstract(self):
        return self.key is None

    def dense(self, name, shape, axes, fan_in=None, zero=False, one=False):
        if self.abstract:
            arr = jax.ShapeDtypeStruct(shape, self.dtype)
        elif one:
            arr = jnp.ones(shape, self.dtype)
        elif zero:
            arr = jnp.zeros(shape, self.dtype)
        else:
            self.key, sub = jax.random.split(self.key)
            fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
            arr = _init(sub, shape, self.dtype, 1.0 / np.sqrt(max(fan, 1)))
        self.params[name] = arr
        self.axes[name] = tuple(axes)
        return arr

    def child(self, name):
        key = None
        if not self.abstract:
            key = jax.random.fold_in(self.key, hash(name) % (2**31))
        sub = ParamBuilder(key, self.dtype)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def build(self):
        return self.params, self.axes


# ---------------------------------------------------------------------------
# norms


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings


def rope(x, positions, theta=10_000.0):
    """x: [..., S, H, Dh]; positions: [..., S] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; full / sliding window / blocked-lazy-softmax / decode)


def attn_init(pb: ParamBuilder, cfg: ModelConfig, L: Optional[int] = None):
    """Stacked ([L] leading) or single-layer attention params.

    When ``cfg.pad_q_heads`` > num_heads (TP-axis adaptation), the padded
    head rows of wq and columns of wo are zero-initialized: padded heads
    compute softmax(0·k)·v through a zero wo column — exact no-ops.
    """
    pre = (L,) if L is not None else ()
    pax = ("layers",) if L is not None else ()
    d, h, kvh, dh = cfg.d_model, cfg.q_heads, cfg.num_kv_heads, cfg.head_dim
    wq = pb.dense("wq", pre + (d, h, dh), pax + ("embed", "heads", "head_dim"), fan_in=d)
    pb.dense("wk", pre + (d, kvh, dh), pax + ("embed", "kv_heads", "head_dim"), fan_in=d)
    pb.dense("wv", pre + (d, kvh, dh), pax + ("embed", "kv_heads", "head_dim"), fan_in=d)
    wo = pb.dense("wo", pre + (h, dh, d), pax + ("heads", "head_dim", "embed"),
                  fan_in=h * dh)
    if h != cfg.num_heads and not pb.abstract:
        # per-KV-group padding: group g holds G real heads then G_pad-G
        # zeroed pads, so _repeat_kv's h -> h // G_pad mapping is preserved
        g_pad = h // kvh
        g_real = cfg.num_heads // kvh
        mask = (jnp.arange(h) % g_pad) < g_real
        pb.params["wq"] = wq * mask[:, None].astype(wq.dtype)
        pb.params["wo"] = wo * mask[:, None, None].astype(wo.dtype)
    if cfg.qk_norm:
        pb.dense("q_norm", pre + (dh,), pax + ("norm",), zero=True)
        pb.dense("k_norm", pre + (dh,), pax + ("norm",), zero=True)


def _qkv(x, p, cfg: ModelConfig, positions, shd: Sharder, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shd(q, "batch", "seq", "act_heads", None)
    k = shd(k, "batch", "seq", "act_kv_heads", None)
    v = shd(v, "batch", "seq", "act_kv_heads", None)
    return q, k, v


def _repeat_kv(k, n_q_heads):
    """GQA: repeat KV heads to the query-head count.

    Keeps the attention einsums in [B,*,H,dh] form with H sharded over the
    TP axis — shardable for ANY kv-head count (kvh that doesn't divide the
    mesh would otherwise force replicated attention).
    """
    kvh = k.shape[2]
    if kvh == n_q_heads:
        return k
    idx = jnp.arange(n_q_heads) // (n_q_heads // kvh)
    return jnp.take(k, idx, axis=2)


def _mask(q_pos, k_pos, *, causal, window, is_global):
    """Attention mask. window/is_global may be traced.

    Unbatched: q_pos [S], k_pos [T] -> bool [S, T].
    Batched (continuous batching: per-slot positions): q_pos [B, S] and/or
    k_pos [B, T] -> bool [B, S, T].
    """
    if q_pos.ndim > 1 or k_pos.ndim > 1:
        qp = (q_pos if q_pos.ndim > 1 else q_pos[None])[:, :, None]
        kp = (k_pos if k_pos.ndim > 1 else k_pos[None])[:, None, :]
    else:
        qp, kp = q_pos[:, None], k_pos[None, :]
    shape = jnp.broadcast_shapes(qp.shape, kp.shape)
    m = jnp.ones(shape, bool)
    if causal:
        m = m & (kp <= qp)
    if window is not None:
        in_win = (qp - kp) < window
        m = m & jnp.where(is_global, True, in_win)
    return m


def attention_scores(q, k, v, mask, scores_f32=True):
    """Naive full attention. q:[B,S,H,Dh] k,v:[B,T,H,Dh] mask:[S,T] or [B,S,T].

    scores_f32=False keeps the score/probability buffers in bf16 (flash-
    style numerics: max-subtracted exp in bf16, f32 denominator) — halves
    the attention HBM traffic on the XLA fallback path; the Pallas kernel
    keeps everything in VMEM regardless.
    """
    dh = q.shape[-1]
    mb = mask[None, None] if mask.ndim == 2 else mask[:, None]  # -> [B|1,1,S,T]
    if scores_f32:
        s = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(dh)
        s = jnp.where(mb, s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhst,bthd->bshd", p, v)
    s = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.asarray(np.sqrt(dh), q.dtype)
    s = jnp.where(mb, s, jnp.asarray(-jnp.inf, s.dtype))
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    m = jnp.maximum(m, jnp.asarray(-1e30, s.dtype))  # all-masked rows
    p = jnp.exp(s - m)                                # bf16, in [0,1]
    denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)  # [B,H,S,1]
    o = jnp.einsum("bhst,bthd->bshd", p, v)
    return o / jnp.maximum(denom, 1e-30).swapaxes(1, 2).astype(o.dtype)


def blocked_attention(q, k, v, q_positions, k_positions, *, causal, window,
                      is_global, q_block=512, scores_f32=True):
    """Memory-bounded attention: scan over query blocks.

    Keeps the live score buffer at [B, H, qb, T] instead of [.., S, T].
    This is the pure-JAX analogue of the flash_attention Pallas kernel; the
    kernel is used on real TPUs, this path is used for lowering/dry-run and
    CPU validation.
    """
    b, s, h, dh = q.shape
    qb = min(q_block, s)
    n_blocks = (s + qb - 1) // qb
    pad = n_blocks * qb - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad), constant_values=-1)
    qs = q.reshape(b, n_blocks, qb, h, dh).transpose(1, 0, 2, 3, 4)
    qpos = q_positions.reshape(n_blocks, qb)

    def body(carry, inp):
        qblk, qp = inp
        m = _mask(qp, k_positions, causal=causal, window=window,
                  is_global=is_global)
        o = attention_scores(qblk, k, v, m, scores_f32)
        return carry, o

    # recompute scores/probs in backward: without this the inner scan stacks
    # per-block probability+mask buffers for the whole sequence (O(S*T))
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = lax.scan(body, None, (qs, qpos))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_blocks * qb, h, dh)
    if pad:
        out = out[:, :s]
    return out


def attention(x, p, cfg: ModelConfig, shd: Sharder, *, positions,
              is_global=True, causal=True, impl="blocked", q_block=512,
              kv_cache=None, cache_pos=None, use_rope=True,
              k_positions=None, k_valid=None, cache_slot=None,
              return_kv=False, scores_f32=True):
    """Full attention module. Returns (out, new_kv_cache_entry).

    kv_cache: optional (k_cache, v_cache) with shape [B, T_max, kvh, Dh];
    when given, behaves as a decode/prefill step writing at ``cache_pos``
    (or ``cache_slot`` when the cache is a ring buffer — then pass explicit
    ``k_positions``/``k_valid`` for the slot->token-position mapping).
    ``cache_pos``/``cache_slot`` may be a [B] vector during single-token
    decode (continuous batching: every batch row sits at its own position;
    pass ``positions`` as [B, 1] to match).
    return_kv: also return the freshly projected (k, v) (used to build
    window ring buffers after a cache-less prefill).
    """
    b, s, d = x.shape
    kvh = cfg.num_kv_heads
    window = cfg.sliding_window if cfg.sliding_window > 0 else None
    q, k, v = _qkv(x, p, cfg, positions, shd, use_rope=use_rope)
    fresh_kv = (k, v)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        write_at = cache_pos if cache_slot is None else cache_slot
        if jnp.ndim(write_at) == 0:
            ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, write_at, 0, 0))
            cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, write_at, 0, 0))
        else:
            assert s == 1, "per-row cache positions require single-token decode"
            bidx = jnp.arange(b)
            ck = ck.at[bidx, write_at].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bidx, write_at].set(v[:, 0].astype(cv.dtype))
        new_cache = (ck, cv)
        k, v = ck, cv
        t_max = ck.shape[1]
        if k_positions is None:
            k_positions = jnp.arange(t_max)
            if jnp.ndim(cache_pos) == 0:
                valid = k_positions < (cache_pos + s)
            else:
                valid = k_positions[None, :] < (cache_pos[:, None] + s)
        else:
            valid = k_valid
    else:
        if k_positions is None:
            k_positions = positions
        valid = k_valid

    # GQA: repeat KV to query-head count; H stays TP-shardable
    k = _repeat_kv(k.astype(q.dtype), cfg.q_heads)
    v = _repeat_kv(v.astype(q.dtype), cfg.q_heads)
    k = shd(k, "batch", None, "act_heads", None)
    v = shd(v, "batch", None, "act_heads", None)
    qg = q

    if s == 1 and kv_cache is not None:
        # decode: single query, direct masked attention over the cache
        m = _mask(positions, k_positions, causal=causal, window=window,
                  is_global=is_global)
        if valid is not None:
            m = m & (valid[None, :] if valid.ndim == 1 else valid[:, None, :])
        o = attention_scores(qg, k, v, m, scores_f32)
    elif impl == "naive":
        m = _mask(positions, k_positions, causal=causal, window=window,
                  is_global=is_global)
        if valid is not None:
            m = m & (valid[None, :] if valid.ndim == 1 else valid[:, None, :])
        o = attention_scores(qg, k, v, m, scores_f32)
    else:
        if valid is not None:
            # prefill into cache: mask invalid tail via positions trick
            o = blocked_attention(qg, k, v, positions, jnp.where(valid, k_positions, 2**30),
                                  causal=causal, window=window,
                                  is_global=is_global, q_block=q_block,
                                  scores_f32=scores_f32)
        else:
            o = blocked_attention(qg, k, v, positions, k_positions,
                                  causal=causal, window=window,
                                  is_global=is_global, q_block=q_block,
                                  scores_f32=scores_f32)

    o = o.reshape(b, s, cfg.q_heads, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    out = shd(out, "batch", "seq", "act_embed")
    if return_kv:
        return out, fresh_kv
    return out, new_cache


# ---------------------------------------------------------------------------
# KV-cache slot management (continuous batching)


def reset_cache_slot(caches, slot, batch_axis=1):
    """Zero one batch row across a KV-cache pytree (slot recycling).

    Caches are stacked [L, B, T, kvh, dh] arrays (or dicts of them for
    local:global window caches); ``batch_axis`` selects the B axis. ``slot``
    may be a traced scalar, so the helper is jit-friendly.
    """
    def _zero(c):
        row = lax.dynamic_slice_in_dim(c, slot, 1, batch_axis)
        return lax.dynamic_update_slice_in_dim(
            c, jnp.zeros_like(row), slot, batch_axis)
    return jax.tree.map(_zero, caches)


def mask_cache_tail(caches, true_len, batch_axis=1):
    """Zero cache entries at positions >= ``true_len`` along the seq axis.

    Right-pad hygiene for bucketed prefill: a prompt padded to its bucket
    edge writes pad-token KV at [true_len, bucket); zeroing that tail keeps
    the invariant that a slot's cache holds exactly its real prefix (decode
    validity masks would hide the pad entries anyway, but a clean cache
    makes bucketed and exact-length prefill states bit-identical).

    Works for flat stacked caches ([L, B, T, kvh, dh]) and the gemma3
    local:global dict: global leaves index the seq axis by absolute
    position; local ring leaves index by ring slot, where ``_ring_gather``
    already zeroed slots beyond the true length (for rings shorter than
    ``true_len`` every slot holds a real position and the mask is a no-op).
    ``true_len`` may be a traced scalar.
    """
    def _mask(c):
        seq_axis = batch_axis + 1
        idx = lax.broadcasted_iota(jnp.int32, c.shape, seq_axis)
        return jnp.where(idx < true_len, c, jnp.zeros((), c.dtype))
    return jax.tree.map(_mask, caches)


def gather_cache_slot(caches, slot, batch_axis=1):
    """Extract one batch row of a cache pytree as a batch-1 cache."""
    return jax.tree.map(
        lambda c: lax.dynamic_slice_in_dim(c, slot, 1, batch_axis), caches)


# ---------------------------------------------------------------------------
# paged KV cache indirection (serve.paging owns the block bookkeeping)
#
# The physical pool stores fixed-size KV blocks: each leaf is
# [L, P, block, kvh, dh] — a contiguous cache whose "batch" axis is the
# block id and whose seq axis is block_size positions. A slot's logical
# cache is defined by its block table (an [NB] row of block ids): logical
# position t lives in pool block ``table[t // block]`` at offset
# ``t % block``. Gathering a table therefore reconstructs a contiguous
# [L, B, NB*block, kvh, dh] view bit-identical to the per-slot cache the
# unpaged steps use — which is exactly the bit-exactness contract the
# paged serving steps are property-tested against.


def paged_gather(pool, tables):
    """Materialize logical cache views through block tables.

    pool leaves: [L, P, block, kvh, dh]; tables: [B, NB] int32 block ids.
    Returns leaves [L, B, NB*block, kvh, dh] — the per-slot contiguous view
    the unmodified model decode/prefill runs on.
    """
    def g(c):
        v = jnp.take(c, tables, axis=1)          # [L, B, NB, block, ...]
        return v.reshape(v.shape[0], tables.shape[0], -1, *v.shape[4:])
    return jax.tree.map(g, pool)


def paged_scatter_block(pool, view, tables, pos):
    """Write back, per batch row, the single block containing ``pos``.

    Decode mutates exactly one position per slot, so only the touched block
    needs to return to the pool. ``pos``: [B] int32 per-slot positions.
    Free slots point at the reserved null block; their duplicate scatter
    indices collide there harmlessly (the null block is never read).
    """
    b = tables.shape[0]
    bidx = jnp.arange(b)

    def s(c, v):
        blk_size = c.shape[2]
        blk = pos // blk_size
        vr = v.reshape(v.shape[0], b, -1, blk_size, *v.shape[3:])
        touched = vr[:, bidx, blk]               # [L, B, block, ...]
        return c.at[:, tables[bidx, blk]].set(touched)
    return jax.tree.map(s, pool, view)


def paged_scatter_slot(pool, view, table_row):
    """Write a batch-1 logical view back through one slot's block table.

    Used after a slot prefill: every view block returns to its pool block.
    Shared prefix blocks are rewritten with the identical bytes the gather
    read (prefill only mutates positions >= its start offset), so other
    owners observe no change; unallocated tail entries scatter into the
    null block.
    """
    def s(c, v):
        blk_size = c.shape[2]
        vr = v.reshape(v.shape[0], -1, blk_size, *v.shape[3:])
        return c.at[:, table_row].set(vr)
    return jax.tree.map(s, pool, view)


def reset_cache_blocks(pool, blocks):
    """Zero a batch of pool blocks (freed-block scrubbing).

    ``blocks``: [K] int32 block ids, padded with the null block id (0) —
    duplicate indices are fine, the scatter just re-zeroes. Keeping freed
    blocks zeroed preserves the invariant that a paged pool is bit-identical
    to a contiguous cache whose slot rows reset on release.
    """
    def z(c):
        shape = (c.shape[0], blocks.shape[0]) + c.shape[2:]
        return c.at[:, blocks].set(jnp.zeros(shape, c.dtype))
    return jax.tree.map(z, pool)


def copy_cache_block(pool, src, dst):
    """Copy one pool block (copy-on-write): dst <- src across every leaf.
    ``src``/``dst`` may be traced scalars."""
    def cp(c):
        blk = lax.dynamic_slice_in_dim(c, src, 1, 1)
        return lax.dynamic_update_slice_in_dim(c, blk, dst, 1)
    return jax.tree.map(cp, pool)


def scatter_cache_slot(caches, update, slot, batch_axis=1):
    """Write a batch-1 cache pytree back into one batch row."""
    return jax.tree.map(
        lambda c, u: lax.dynamic_update_slice_in_dim(
            c, u.astype(c.dtype), slot, batch_axis), caches, update)


# ---------------------------------------------------------------------------
# mixed-axis state trees (recurrent serving)
#
# Recurrent families stack per-layer state with the batch on DIFFERENT axes
# per leaf: xlstm mLSTM/conv leaves are [L, B, ...] (axis 1) while its sLSTM
# leaves are [B, ...] (axis 0); zamba2 mixes [L, B, ...] mamba state with
# [B, T, ...] attention KV. These helpers take an ``axes`` pytree (same
# structure as ``state``, int batch-axis per leaf — inferred once by
# ``serve.state`` from two ``jax.eval_shape``s of ``init_cache``) so one
# gather/scatter pair serves every family.


def gather_state_slot(state, slot, axes):
    """Extract one batch row of a mixed-axis state tree as a batch-1 tree.
    ``slot`` may be a traced scalar."""
    return jax.tree.map(
        lambda c, ax: lax.dynamic_slice_in_dim(c, slot, 1, ax), state, axes)


def scatter_state_slot(state, update, slot, axes):
    """Write a batch-1 mixed-axis state tree back into one batch row.

    Scattering a freshly-initialized batch-1 template is also how a slot is
    *reset*: every leaf row is overwritten wholesale, so no stale carried
    state (or KV) from a prior occupant survives slot reuse."""
    return jax.tree.map(
        lambda c, u, ax: lax.dynamic_update_slice_in_dim(
            c, u.astype(c.dtype), slot, ax), state, update, axes)


# ---------------------------------------------------------------------------
# MLPs


def mlp_init(pb: ParamBuilder, d_model, d_ff, L: Optional[int] = None,
             hidden_axis="mlp"):
    pre = (L,) if L is not None else ()
    pax = ("layers",) if L is not None else ()
    pb.dense("w_gate", pre + (d_model, d_ff), pax + ("embed", hidden_axis), fan_in=d_model)
    pb.dense("w_up", pre + (d_model, d_ff), pax + ("embed", hidden_axis), fan_in=d_model)
    pb.dense("w_down", pre + (d_ff, d_model), pax + (hidden_axis, "embed"), fan_in=d_ff)


def mlp(x, p, shd: Sharder, hidden_axis="act_mlp"):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = shd(h, "batch", "seq", hidden_axis)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return shd(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# embeddings & loss


def embed_init(pb: ParamBuilder, cfg: ModelConfig):
    pb.dense("embedding", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
             fan_in=cfg.d_model)
    pb.dense("unembed", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
             fan_in=cfg.d_model)
    pb.dense("final_norm", (cfg.d_model,), ("norm",), zero=True)


def embed(tokens, p, dtype):
    return p["embedding"].astype(dtype)[tokens]


def unembed(x, p, shd: Sharder):
    x = rms_norm(x, p["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(x.dtype))
    return shd(logits, "batch", "seq", "act_vocab")


def chunked_softmax_xent(h, params, labels, mask=None, n_chunks=16):
    """Cross-entropy without materializing [B,S,V] logits.

    Online logsumexp over vocab chunks; each chunk's logits are recomputed
    in the backward pass (jax.checkpoint), so peak memory is O(B*S*V/n).
    """
    hn = rms_norm(h, params["final_norm"])
    w = params["unembed"]
    v = w.shape[1]
    c = v // n_chunks
    assert v % n_chunks == 0, (v, n_chunks)
    b, s, _ = h.shape

    def body(carry, i):
        m_run, s_run, gold = carry
        wc = lax.dynamic_slice_in_dim(w, i * c, c, 1).astype(hn.dtype)
        lo = jnp.einsum("bsd,dc->bsc", hn, wc).astype(jnp.float32)
        m_new = jnp.maximum(m_run, jnp.max(lo, axis=-1))
        s_run = (s_run * jnp.exp(m_run - m_new)
                 + jnp.sum(jnp.exp(lo - m_new[..., None]), axis=-1))
        in_range = (labels >= i * c) & (labels < (i + 1) * c)
        idx = jnp.clip(labels - i * c, 0, c - 1)
        g = jnp.take_along_axis(lo, idx[..., None], axis=-1)[..., 0]
        gold = gold + jnp.where(in_range, g, 0.0)
        return (m_new, s_run, gold), None

    init = (jnp.full((b, s), -1e30, jnp.float32),
            jnp.zeros((b, s), jnp.float32),
            jnp.zeros((b, s), jnp.float32))
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m_run, s_run, gold), _ = lax.scan(body, init, jnp.arange(n_chunks))
    nll = jnp.log(jnp.maximum(s_run, 1e-30)) + m_run - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy in fp32; labels: int [B,S]."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
