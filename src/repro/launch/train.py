"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-20b --smoke \
        --steps 100 --batch 8 --seq 256

--smoke uses the reduced same-family config (CPU-runnable); on a TPU
deployment drop --smoke and set --mesh-data/--mesh-model to the pod shape.
Integrates checkpointing (atomic, resumable), ``repro.telemetry``
energy monitoring (J/token, per-tag attribution), and the energy-aware loop.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ShapeConfig
from repro.core.tracing import TraceStats, counting_jit
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.obs import MetricsRegistry, Tracer, write_chrome_trace
from repro.train import loop as loop_mod
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import StepConfig, TrainState, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--power-cap-w", type=float, default=None)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--log-json", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/chrome-trace timeline JSON of "
                         "the run (train_step/checkpoint spans with "
                         "per-span attributed joules)")
    ap.add_argument("--metrics-json", default=None,
                    help="write the metrics-registry snapshot "
                         "(deterministic JSON)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = None
    if args.mesh_data * args.mesh_model > 1:
        mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)

    model = build_model(cfg, mesh, q_block=min(512, args.seq))
    params, axes = model.init(jax.random.key(0))
    state = TrainState(params, init_opt_state(params))

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                        total_steps=args.steps)
    step_cfg = StepConfig(num_microbatches=args.micro)
    train_step = make_train_step(model, opt_cfg, step_cfg)
    # counting_jit (not bare jax.jit): a training retrace burns the same
    # silent watts a serving retrace does — the stats land in the summary
    trace_stats = TraceStats()
    if mesh is not None:
        from repro.train.step import batch_specs, shardings, state_specs
        from repro.models import token_batch_specs
        ssh = shardings(mesh, state_specs(mesh, params, axes))
        train_step = counting_jit(train_step, "train_step", trace_stats,
                                  in_shardings=(ssh, None),
                                  donate_argnums=(0,))
    else:
        train_step = counting_jit(train_step, "train_step", trace_stats,
                                  donate_argnums=(0,))

    data = SyntheticTokens(
        DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch), cfg)
    loop_cfg = loop_mod.LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, power_cap_w=args.power_cap_w)

    def on_step(rec):
        if rec["step"] % 10 == 0 or rec["step"] == 1:
            print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                  f"gnorm {rec['grad_norm']:.3f} {rec['wall_s']*1e3:.0f}ms "
                  f"E={rec['energy_j']:.1f}J")

    tracer = Tracer() if args.trace_out else None
    registry = MetricsRegistry()
    state, history, summary = loop_mod.run(
        train_step, state, data, loop_cfg, on_step=on_step, tracer=tracer,
        metrics_registry=registry)
    session = summary.pop("session", None)   # live object, not JSON
    summary["train_step_compiles"] = trace_stats.compiles("train_step")
    print(f"final loss {history[-1]['loss']:.4f}  "
          f"J/token {summary['j_per_token']:.4f}  "
          f"avg {summary['avg_power_w']:.1f} W  "
          f"tags {list(summary['energy_by_tag'])}")
    if args.trace_out and tracer is not None:
        write_chrome_trace(args.trace_out, tracer, session=session,
                           meta={"process": "dalek-train",
                                 "arch": cfg.name, "steps": args.steps})
        print(f"timeline -> {args.trace_out}")
    if args.metrics_json:
        registry.write_json(args.metrics_json)
        print(f"metrics -> {args.metrics_json}")
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump({"history": history, "summary": summary}, f, default=float)
    return history


if __name__ == "__main__":
    main()
