"""Unified observability layer: spans, metrics, timeline export.

The execution-time counterpart of ``repro.telemetry`` (which measures
joules): request-lifecycle and engine-step spans (:mod:`~repro.obs.spans`),
a labeled Counter/Gauge/Histogram registry (:mod:`~repro.obs.metrics`), a
typed telemetry-event schema shared with the trace store
(:mod:`~repro.obs.events`), and Chrome-trace/Perfetto export that merges
spans with ``MonitorSession`` energy windows so every span carries
attributed joules (:mod:`~repro.obs.export`).
"""
from repro.obs.events import (TelemetryEvent, coerce_event, events_from_meta,
                              events_to_meta, window_of)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.spans import NULL_SPAN, Span, SpanRecord, Tracer, span_tree
from repro.obs.export import (chrome_trace, parse_chrome_trace,
                              session_energies, timeline_from_trace,
                              validate_chrome_trace, write_chrome_trace)

__all__ = [
    "TelemetryEvent", "coerce_event", "events_to_meta", "events_from_meta",
    "window_of",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Tracer", "Span", "SpanRecord", "NULL_SPAN", "span_tree",
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "parse_chrome_trace", "timeline_from_trace", "session_energies",
]
