"""Per-slot KV-cache bookkeeping for continuous batching.

Each batch row of the shared KV cache is a *slot*. A slot is bound to one
request from prefill until EOS/length, then recycled for the next queued
request while the other slots keep decoding — the cache itself never
reshapes, only the slot's position/ownership state changes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serve.queue import Request


@dataclasses.dataclass
class Slot:
    index: int
    req: Optional[Request] = None
    pos: int = 0            # cache position the *next* token writes to
    last_token: int = 0     # token fed to the next decode step

    @property
    def free(self) -> bool:
        return self.req is None


class SlotManager:
    """Slot lifecycle: assign at prefill, advance per decode, release+recycle."""

    def __init__(self, batch_size: int, max_seq: int):
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.slots: List[Slot] = [Slot(i) for i in range(batch_size)]
        self.n_assigned = 0
        self.n_released = 0
        self.n_prefill_tokens = 0   # true prompt tokens (bucket pad excluded)
        self.peak_active = 0

    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.free]

    def active_slots(self) -> List[Slot]:
        return [s for s in self.slots if not s.free]

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if not s.free)

    def assign(self, slot: Slot, req: Request, first_token: int):
        """Bind ``req`` after its prefill wrote cache [0, len(prompt)).

        ``slot.pos`` is always the TRUE prompt length: a bucketed prefill
        right-pads to its bucket edge but scatters only the real prefix, so
        decode resumes at the true position, not the padded one. The prompt
        must leave at least one decode position; a generation budget beyond
        capacity is fine — the engine finishes the request at capacity
        (``at_capacity``) instead of truncating the budget up front."""
        assert slot.free, f"slot {slot.index} busy"
        assert len(req.prompt) < self.max_seq, (
            f"request {req.req_id}: prompt of {len(req.prompt)} leaves no "
            f"decode position in a {self.max_seq}-position cache")
        slot.req = req
        slot.pos = len(req.prompt)
        slot.last_token = first_token
        self.n_assigned += 1
        self.n_prefill_tokens += len(req.prompt)
        self.peak_active = max(self.peak_active, self.n_active)

    def advance(self, slot: Slot, token: int):
        """Record one decoded token: the fed token landed at ``pos``.

        ``pos`` is NOT clamped at ``max_seq - 1``: clamping silently
        overwrote the last KV position every subsequent step (stale
        attention, corrupted cache). The engine checks ``at_capacity`` after
        each advance and finishes the request (finish_reason "capacity")
        instead of letting it wrap."""
        slot.pos += 1
        slot.last_token = token

    def at_capacity(self, slot: Slot) -> bool:
        """True when the next decode would write past the cache: the
        request must finish now (finish_reason "capacity")."""
        return slot.pos > self.max_seq - 1

    def release(self, slot: Slot):
        slot.req = None
        slot.pos = 0
        slot.last_token = 0
        self.n_released += 1

    def batch_tokens(self) -> np.ndarray:
        """[B, 1] int32 next-token inputs (free slots feed token 0)."""
        return np.array([[s.last_token] for s in self.slots], np.int32)

    def batch_positions(self) -> np.ndarray:
        """[B] int32 per-slot cache positions (free slots pinned at 0;
        their writes land in recycled rows that the next prefill
        overwrites)."""
        return np.array([min(s.pos, self.max_seq - 1) for s in self.slots],
                        np.int32)
